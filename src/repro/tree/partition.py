"""Recursive range-partition front end for unknown/adversarial d (§15).

PBS needs a sane d̂; a cold-start peer, a replica returning after long
downtime, or an adversarially divergent one (d ≈ |A|) sits outside the
ToW-estimator operating regime (``EstimateOutOfRange``).  Following the
divide-and-conquer family of tree reconciliation algorithms, the front end
splits the 32-bit key space into a binary range tree and walks it level by
level: each frontier range gets a cheap digest — element count, 32-bit
checksum, and a small-ℓ ToW sketch — and the per-range verdict is

* ``TREE_PRUNE``   — digests agree: no symmetric difference in the range,
* ``TREE_LEAF``    — divergent with small residual d̂: hand the range to
  PBS as an ordinary known-d session,
* ``TREE_RECURSE`` — divergent and still hot: split in half and go deeper.

A whole level's digests are one batched, padded+masked ``tree_digest``
kernel sweep (rows/row-length at ``pow2_bucket`` shapes so the warm-jit
cache holds across frontiers, DESIGN.md §12): the in-process walk stacks
both sides into a single launch per level, the wire peers run one launch
per side.  Residual d̂ per range reuses the phase-0 estimator algebra
(numerator Σ(ΔY)², ``planned_d`` inflation) capped by the range's total
element count, which also guarantees termination: once a range's width —
halved every level — drops under ``leaf_d``, its count bound forces a leaf
verdict, so depth never exceeds ``KEY_BITS - floor(log2(leaf_d))`` even
for adversarially clustered keys (uniform pairs leaf out around
``log2(gamma * d / leaf_d)`` levels).  Byte accounting mirrors the wire:
``digest_bytes`` is the exact framed size of the ``MSG_TREE`` digest +
verdict exchange — transport-side overhead, split from the PBS Formula-(1)
ledger bits the leaf sessions report.
"""
from __future__ import annotations

from dataclasses import dataclass, replace as _dc_replace

import numpy as np

from repro.core.hashing import derive_seed
from repro.core.pbs import KEY_BITS, PBSConfig
from repro.core.tow import GAMMA, planned_d, tow_seeds, tow_sketches
from repro.kernels.platform import pow2_bucket, retrace_count
from repro.kernels.tree_digest import tree_digest
from repro.obs.trace import NULL_TRACER
from repro.wire import frames as wf

SPAN = 1 << KEY_BITS
_TREE_SEED_TAG = 0x7EE  # domain-separates tree digests from phase-0 ToW


@dataclass(frozen=True)
class TreeConfig:
    """Tree-phase parameters; both peers must hold identical values
    (positional contract, like ``PBSConfig``/``d_known`` on sessions).

    ``ell`` is the per-range sketch length (small: range digests only need
    a coarse residual d̂, not phase-0 precision); ``leaf_d`` is the planned
    d̂ at or below which a divergent range goes to PBS; ``max_depth`` hard-
    caps recursion (any still-divergent range leafs out there).
    """

    ell: int = 32
    leaf_d: int = 48
    gamma: float = GAMMA
    max_depth: int = KEY_BITS
    seed: int = 0
    row_floor: int = 8     # pow2_bucket floor for frontier rows
    tile: int = 512        # kernel element-tile (and row-length floor)


@dataclass(frozen=True)
class TreeLeaf:
    """One divergent range handed to PBS: ``[lo, hi)`` with planned d."""

    lo: int
    hi: int
    d_plan: int


@dataclass
class TreeStats:
    """Walk ledger: one entry per ``partition_pair``/tree phase."""

    levels: int = 0         # digest-exchange barriers executed
    depth: int = 0          # deepest level index reached (root = 0)
    leaves: int = 0
    pruned: int = 0
    recursed: int = 0
    max_frontier: int = 0
    digest_bytes: int = 0   # framed MSG_TREE digest + verdict bytes
    launches: int = 0       # tree_digest kernel launches
    retraces: int = 0       # jit retraces during the walk

    def as_dict(self) -> dict:
        return {
            "tree_levels": self.levels,
            "tree_leaves": self.leaves,
            "tree_digest_bytes": self.digest_bytes,
        }


def tree_seeds(tcfg: TreeConfig) -> np.ndarray:
    """The walk's shared ToW seed family (distinct from phase 0's)."""
    return tow_seeds(derive_seed(tcfg.seed, _TREE_SEED_TAG), tcfg.ell)


def split_ranges(frontier, verdicts) -> list[tuple[int, int]]:
    """Next level's frontier: every ``TREE_RECURSE`` range halved, in
    range order — the deterministic rule both peers apply to stay
    frontier-aligned without ever shipping range bounds."""
    nxt: list[tuple[int, int]] = []
    for (lo, hi), v in zip(frontier, verdicts):
        if v == wf.TREE_RECURSE:
            mid = (lo + hi) // 2
            nxt.append((lo, mid))
            nxt.append((mid, hi))
    return nxt


def range_bounds(elems: np.ndarray, frontier) -> tuple[np.ndarray, np.ndarray]:
    """(lo_idx, hi_idx) slice bounds of each frontier range in a sorted
    key array (int64 search: ``hi`` may be 2**32)."""
    los = np.array([lo for lo, _ in frontier], dtype=np.int64)
    his = np.array([hi for _, hi in frontier], dtype=np.int64)
    return np.searchsorted(elems, los), np.searchsorted(elems, his)


def _range_matrix(elems, lo_idx, counts, width):
    """Pack range slices into rows of a (R, width) matrix + 0/1 mask."""
    n_r = len(lo_idx)
    col = np.arange(width, dtype=np.int64)[None, :]
    valid = (col < counts[:, None]).astype(np.int32)
    idx = lo_idx[:, None] + col
    if len(elems):
        mat = elems[np.minimum(idx, len(elems) - 1)].astype(np.uint32)
    else:
        mat = np.zeros((n_r, width), dtype=np.uint32)
    return mat * valid.astype(np.uint32), valid


def _checksums(prefix: np.ndarray, lo_idx, hi_idx) -> np.ndarray:
    """Per-range ``core.pbs.checksum`` (sum mod 2**32) from a prefix-sum."""
    return ((prefix[hi_idx] - prefix[lo_idx]) & np.uint64(0xFFFFFFFF)).astype(
        np.int64
    )


def _checksum_prefix(elems: np.ndarray) -> np.ndarray:
    return np.concatenate(
        [np.zeros(1, np.uint64), np.cumsum(elems, dtype=np.uint64)]
    )


def level_digests(
    elems: np.ndarray,
    frontier,
    tcfg: TreeConfig,
    *,
    interpret: bool | None = None,
    launches: dict | None = None,
    prefix: np.ndarray | None = None,
):
    """One side's frontier digests: (counts, checksums, (R, ell) sketches).

    One ``tree_digest`` launch for the whole frontier, padded to
    ``pow2_bucket`` rows and row length so repeat walks hit the warm jit
    cache (``stats["retraces"] == 0`` after warmup).
    """
    lo_idx, hi_idx = range_bounds(elems, frontier)
    counts = (hi_idx - lo_idx).astype(np.int64)
    if prefix is None:
        prefix = _checksum_prefix(elems)
    csums = _checksums(prefix, lo_idx, hi_idx)
    n_r = len(frontier)
    rows = pow2_bucket(n_r, tcfg.row_floor)
    width = pow2_bucket(max(int(counts.max()) if n_r else 1, 1), tcfg.tile)
    mat = np.zeros((rows, width), dtype=np.uint32)
    valid = np.zeros((rows, width), dtype=np.int32)
    mat[:n_r], valid[:n_r] = _range_matrix(elems, lo_idx, counts, width)
    sk = tree_digest(
        mat, valid, tree_seeds(tcfg),
        ell=tcfg.ell, tile=tcfg.tile, interpret=interpret,
    )
    if launches is not None:
        launches["kernel_launches"] = launches.get("kernel_launches", 0) + 1
    return counts, csums, np.asarray(sk)[:n_r].astype(np.int64)


def level_digests_ref(elems: np.ndarray, frontier, tcfg: TreeConfig):
    """Pure-host oracle of ``level_digests`` (per-range ``tow_sketches``
    loop) — the differential baseline for tests/test_tree_conformance.py."""
    lo_idx, hi_idx = range_bounds(elems, frontier)
    counts = (hi_idx - lo_idx).astype(np.int64)
    csums = _checksums(_checksum_prefix(elems), lo_idx, hi_idx)
    seed = derive_seed(tcfg.seed, _TREE_SEED_TAG)
    sk = np.zeros((len(frontier), tcfg.ell), dtype=np.int64)
    for r in range(len(frontier)):
        sk[r] = tow_sketches(elems[lo_idx[r] : hi_idx[r]], seed, tcfg.ell)
    return counts, csums, sk


def level_verdicts(
    level: int,
    cnt_a, cs_a, sk_a,
    cnt_b, cs_b, sk_b,
    tcfg: TreeConfig,
):
    """Per-range verdicts + leaf d plans, deterministic from both digest
    sets — the responder computes this and ships it in a ``TREE_VERDICT``
    frame; the in-process walk calls it directly.

    The planned leaf d is the phase-0 estimator algebra at tree ℓ
    (``planned_d(Σ(ΔY)²/ℓ, gamma)``) clamped to ``[1, cnt_a + cnt_b]`` —
    the clamp both tightens trivially-small ranges and forces every range
    to leaf out once halving shrinks its element count under ``leaf_d``.
    """
    cnt_a = np.asarray(cnt_a, dtype=np.int64)
    cnt_b = np.asarray(cnt_b, dtype=np.int64)
    num = np.sum((np.asarray(sk_a) - np.asarray(sk_b)) ** 2, axis=1)
    equal = (cnt_a == cnt_b) & (np.asarray(cs_a) == np.asarray(cs_b)) & (num == 0)
    d_plan = np.array(
        [planned_d(n / tcfg.ell, tcfg.gamma) for n in num], dtype=np.int64
    )
    d_plan = np.maximum(np.minimum(d_plan, cnt_a + cnt_b), 1)
    width = SPAN >> level
    at_floor = level >= tcfg.max_depth or width <= 1
    leaf = ~equal & (at_floor | (d_plan <= tcfg.leaf_d))
    verdicts = np.full(len(num), wf.TREE_RECURSE, dtype=np.int64)
    verdicts[equal] = wf.TREE_PRUNE
    verdicts[leaf] = wf.TREE_LEAF
    return verdicts, d_plan[leaf]


def partition_pair(
    set_a: np.ndarray,
    set_b: np.ndarray,
    tree: TreeConfig | None = None,
    *,
    interpret: bool | None = None,
    tracer=None,
) -> tuple[list[TreeLeaf], TreeStats]:
    """In-process tree walk over both sides -> (PBS leaves, stats).

    Both sides' frontier digests ride ONE stacked kernel launch per level
    (wire peers run one launch per side, ≤ 2 per level either way); the
    ``digest_bytes`` ledger is the exact framed ``MSG_TREE`` exchange the
    wire flow would ship for the same pair.
    """
    tcfg = tree or TreeConfig()
    tracer = tracer if tracer is not None else NULL_TRACER
    a = np.unique(np.asarray(set_a, dtype=np.uint32))
    b = np.unique(np.asarray(set_b, dtype=np.uint32))
    stats = TreeStats()
    retrace_mark = retrace_count()
    prefix_a, prefix_b = _checksum_prefix(a), _checksum_prefix(b)
    seeds = tree_seeds(tcfg)
    frontier: list[tuple[int, int]] = [(0, SPAN)]
    leaves: list[TreeLeaf] = []
    level = 0
    while frontier:
        stats.levels += 1
        stats.depth = level
        stats.max_frontier = max(stats.max_frontier, len(frontier))
        n_r = len(frontier)
        with tracer.span("tree.level.dispatch", level=level, ranges=n_r):
            lo_a, hi_a = range_bounds(a, frontier)
            lo_b, hi_b = range_bounds(b, frontier)
            cnt_a = (hi_a - lo_a).astype(np.int64)
            cnt_b = (hi_b - lo_b).astype(np.int64)
            rows = pow2_bucket(n_r, tcfg.row_floor)
            width = pow2_bucket(
                max(int(max(cnt_a.max(), cnt_b.max())) if n_r else 1, 1),
                tcfg.tile,
            )
            mat = np.zeros((2 * rows, width), dtype=np.uint32)
            valid = np.zeros((2 * rows, width), dtype=np.int32)
            mat[:n_r], valid[:n_r] = _range_matrix(a, lo_a, cnt_a, width)
            mat[rows : rows + n_r], valid[rows : rows + n_r] = _range_matrix(
                b, lo_b, cnt_b, width
            )
            sk = tree_digest(  # one launch: both sides stacked
                mat, valid, seeds,
                ell=tcfg.ell, tile=tcfg.tile, interpret=interpret,
            )
            stats.launches += 1
        with tracer.span("tree.level.collect", level=level, ranges=n_r):
            sk = np.asarray(sk).astype(np.int64)
            sk_a, sk_b = sk[:n_r], sk[rows : rows + n_r]
            cs_a = _checksums(prefix_a, lo_a, hi_a)
            cs_b = _checksums(prefix_b, lo_b, hi_b)
            verdicts, leaf_ds = level_verdicts(
                level, cnt_a, cs_a, sk_a, cnt_b, cs_b, sk_b, tcfg
            )
            # ledger: the framed exchange the wire peers would ship
            stats.digest_bytes += len(
                wf.encode_tree_digest(level, cnt_a, cs_a, sk_a)
            ) + len(wf.encode_tree_verdict(level, verdicts, leaf_ds))
            for (lo, hi), v, dp in _iter_leaves(frontier, verdicts, leaf_ds):
                leaves.append(TreeLeaf(lo=lo, hi=hi, d_plan=int(dp)))
            stats.pruned += int(np.sum(verdicts == wf.TREE_PRUNE))
            stats.recursed += int(np.sum(verdicts == wf.TREE_RECURSE))
            frontier = split_ranges(frontier, verdicts)
        level += 1
    stats.leaves = len(leaves)
    stats.retraces = retrace_count() - retrace_mark
    return leaves, stats


def _iter_leaves(frontier, verdicts, leaf_ds):
    """Yield ((lo, hi), verdict, d_plan) for each TREE_LEAF in range order."""
    li = 0
    for (lo, hi), v in zip(frontier, verdicts):
        if v == wf.TREE_LEAF:
            yield (lo, hi), v, leaf_ds[li]
            li += 1


def leaf_slices(elems: np.ndarray, leaves) -> list[np.ndarray]:
    """Each leaf range's slice of a sorted key array, leaf order."""
    lo_idx, hi_idx = range_bounds(
        elems, [(leaf.lo, leaf.hi) for leaf in leaves]
    )
    return [elems[lo_idx[i] : hi_idx[i]] for i in range(len(leaves))]


@dataclass
class TreeResult:
    """Outcome of a full tree+PBS reconciliation (``tree_reconcile``).

    ``diff`` is the union of every leaf session's recovered symmetric
    difference — the same set ``core.pbs.reconcile`` reports for the whole
    pair.  ``tree_bytes`` (framed ``MSG_TREE`` exchange) is transport-side;
    ``pbs_bytes`` is the leaf sessions' Formula-(1) ledger sum.
    """

    diff: set
    success: bool
    leaves: list[TreeLeaf]
    stats: TreeStats
    results: dict
    tree_bytes: int
    pbs_bytes: int

    @property
    def total_bytes(self) -> int:
        return self.tree_bytes + self.pbs_bytes

    def bytes_per_diff(self) -> float:
        return self.total_bytes / max(1, len(self.diff))


def tree_reconcile(
    set_a: np.ndarray,
    set_b: np.ndarray,
    cfg: PBSConfig | None = None,
    tree: TreeConfig | None = None,
    *,
    interpret: bool | None = None,
    rateless: bool = False,
    recorder=None,
    tracer=None,
) -> TreeResult:
    """Full cold-start reconciliation: tree front end, then every leaf as
    an ordinary known-d PBS session fused into one ``ReconcileServer``
    batch (graceful degradation on, so an underestimated leaf escalates
    instead of failing).  ``rateless=True`` arms the ``MSG_PARITY``
    recovery ladder (DESIGN.md §16) on every leaf session: a leaf whose
    tree-estimated d̂ undershot recovers in-round by extending its BCH
    sketches instead of burning a doubled-d̂ re-plan — degradation stays
    on as the fallback past the extension cap.  Publishes the
    ``server.tree_*`` metrics.
    """
    from repro.recon.server import ReconcileServer

    cfg = cfg or PBSConfig()
    if rateless and not cfg.rateless:
        cfg = _dc_replace(cfg, rateless=True)
    a = np.unique(np.asarray(set_a, dtype=np.uint32))
    b = np.unique(np.asarray(set_b, dtype=np.uint32))
    leaves, stats = partition_pair(
        a, b, tree, interpret=interpret, tracer=tracer
    )
    server = ReconcileServer(
        interpret=interpret, degrade=True, recorder=recorder, tracer=tracer
    )
    results: dict = {}
    diff: set = set()
    success = True
    pbs_bytes = 0
    if leaves:
        for a_sub, b_sub, leaf in zip(
            leaf_slices(a, leaves), leaf_slices(b, leaves), leaves
        ):
            server.submit(a_sub, b_sub, cfg, d_known=leaf.d_plan)
        results = server.run()
        for res in results.values():
            diff |= res.diff
            success = success and res.success
            pbs_bytes += res.bytes_sent
    server.recorder.publish(
        "server",
        dict(
            stats.as_dict(),
            tree_bytes_per_diff=(stats.digest_bytes + pbs_bytes)
            / max(1, len(diff)),
        ),
    )
    return TreeResult(
        diff=diff,
        success=success,
        leaves=leaves,
        stats=stats,
        results=results,
        tree_bytes=stats.digest_bytes,
        pbs_bytes=pbs_bytes,
    )
