"""Multi-peer reconciliation hub: one endpoint serving N peers (DESIGN.md §10).

``HubEndpoint`` is the serving (Bob) side of N concurrent PBS sessions'
worth of peers: every peer connects over its own ``Transport``, is assigned
a **channel id**, and exchanges ``repro.wire`` frames wrapped in the
``MSG_MUX`` envelope tagged with that id — a frame carrying any other id
(unknown, stale, zero, or unwrapped) is rejected and fails only that peer.
Peers run stock ``AliceEndpoint``s constructed with ``channel=``; their
protocol, ledgers, and results are byte-identical to the pair path.

The point of the hub is *fusion*: all peers' sessions feed **one shared**
``SessionBatch(sides=("b",))``, so a global round packs every peer's active
units into the same per-code cohorts — one ``encode_side`` (one
``bin_parity_xorsum_units`` launch + one GF(2) sketch matmul) and one
``bch_decode_batched`` launch per cohort, shared across all N peers,
instead of N independent pipelines.

Scenario diversity the pair path never sees (all exercised in
tests/test_hub.py and tests/test_protocol_conformance.py):

* **peers joining between global rounds** — a session admitted after global
  round k carries ``rnd0 = k``; all protocol-visible round arithmetic (bin
  seeds, budget, frame round numbers) uses its *local* round, so a late
  joiner is byte-identical to a pair that started alone;
* **stragglers** — the round barrier polls every peer with a per-peer
  deadline from barrier start; a peer whose frame does not arrive in time
  is evicted (its sessions fail with the deadline ``TransportError``) and
  the round proceeds with the survivors;
* **mid-protocol disconnect** — any non-timeout transport failure or
  malformed frame evicts just that peer, surfacing as a clean per-peer
  error in its ``PeerOutcome`` while every other peer completes untouched;
* **mixed known-d and estimator peers** — estimator sessions run their
  phase-0 ToW exchange at admission, then share cohorts with known-d
  sessions as usual;
* **continuous epochs** (``continuous=True``, DESIGN.md §11) — after every
  peer's epoch settles, ``advance_epoch`` stages each side's churn, the
  next ``serve`` opens with a ``MSG_EPOCH`` handshake barrier (epoch id +
  per-estimator-session d̂ re-estimation), and the shared cohort stores
  take an in-place O(churn) delta patch instead of a rebuild — sessions,
  channels, and device residency all survive across epochs
  (tests/test_sync_churn.py soaks ≥20 epochs against the oracle).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.core.pbs import (
    PBSConfig,
    ReconcileResult,
    new_session_state,
    plan_from_d_known,
    queue_split,
    session_live,
)
from repro.recon.session import (
    ReconSession,
    SessionBatch,
    advance_session,
    apply_churn,
)
from repro.kernels.platform import enable_persistent_cache, retrace_count
from repro.wire import frames as wf
from repro.wire.frames import WireError
from repro.wire.varint import framed_len

from .endpoint import (
    AliceEndpoint,
    decode_side_b_round,
    encode_round_rows,
    round_schema,
    serve_epoch_frame,
    serve_phase0,
    stream_wire_stats,
    verify_ack_entries,
)
from .transport import FrameStream, Transport, TransportError, TransportTimeout

_EMPTY = np.zeros(0, dtype=np.uint32)
_POLL_S = 0.02  # barrier round-robin slice: bounds one sweep over N peers


@dataclass
class PeerOutcome:
    """One peer's final disposition after ``serve``."""

    channel: int
    ok: bool                            # verify exchange completed
    verified: list[bool] | None         # per-session verdicts (ok peers)
    error: BaseException | None         # eviction cause (failed peers)
    sessions: list[ReconSession]        # the hub's mirrored session states
    wire_stats: dict


class _Peer:
    """Hub-side connection state for one channel."""

    def __init__(self, channel: int, transport: Transport, label: str | None):
        self.channel = channel
        self.label = label or f"peer{channel}"
        self.transport = transport
        self.stream = FrameStream(transport, channel=channel)
        self.pending: list[tuple] = []      # (set_b, cfg, d_known) pre-admission
        self.sessions: list[ReconSession] = []  # local-sid order
        self.admitted = False
        self.retired = False
        self.verified: list[bool] | None = None
        self.error: BaseException | None = None
        self.tally = {"estimator": 0, "protocol": 0, "verify": 0, "epoch": 0}
        self.d_known: list[int | None] = []     # per local sid, epoch default
        self.epoch_pending: dict[int, tuple] | None = None  # sid -> (set_b, dk)
        self.epoch_plans: dict[int, object] = {}

    def wire_stats(self) -> dict:
        return stream_wire_stats(self.stream, self.tally)


class HubEndpoint:
    """One serving endpoint reconciling against N peers concurrently.

    Usage::

        hub = HubEndpoint()
        ch = hub.add_peer(transport)          # one Transport per peer
        hub.submit(ch, set_b, cfg=cfg, d_known=d)   # positional, like a pair
        outcomes = hub.serve()                # dict channel -> PeerOutcome

    ``add_peer``/``submit`` may also be called while ``serve`` runs (from
    another thread, or from the ``on_barrier`` hook): the peer is admitted
    at the next global-round barrier with ``rnd0`` = the completed round.
    ``recv_deadline`` is the per-peer barrier deadline; ``on_barrier`` (if
    set) is called with the just-completed global round number — the
    deterministic injection point tests use for mid-run joins.
    """

    side = "b"

    def __init__(
        self,
        *,
        interpret: bool | None = None,
        recv_deadline: float = 60.0,
        on_barrier=None,
        continuous: bool = False,
    ):
        enable_persistent_cache()
        self._interpret = interpret
        self._deadline = recv_deadline
        self.on_barrier = on_barrier
        self._continuous = continuous
        self._lock = threading.Lock()
        self._peers: dict[int, _Peer] = {}
        self._order: list[int] = []         # admission order of channels
        self._joiners: list[int] = []       # added but not yet admitted
        self._next_channel = 1
        self.stale_channels: set[int] = set()
        self._sessions: list[ReconSession] = []
        self._batch = SessionBatch(
            self._sessions, sides=(self.side,), mutable=continuous
        )
        self._stats: dict = {}
        self._epoch = 0
        self._epoch_open = False

    # -- registration ----------------------------------------------------

    def add_peer(self, transport: Transport, *, label: str | None = None) -> int:
        """Register a peer connection; returns its channel id (never 0,
        never reused — a retired channel's id stays stale forever)."""
        with self._lock:
            ch = self._next_channel
            self._next_channel += 1
            self._peers[ch] = _Peer(ch, transport, label)
            self._joiners.append(ch)
        return ch

    def submit(
        self,
        channel: int,
        set_b,
        cfg: PBSConfig | None = None,
        d_known: int | None = None,
    ) -> int:
        """Enqueue this hub's side of the peer's next session (positional
        pairing with the peer's ``submit`` order, like the pair path);
        returns the peer-local sid.  Must precede the peer's admission."""
        peer = self._peers[channel]
        elems = np.unique(np.asarray(set_b, dtype=np.uint32))
        with self._lock:
            if peer.admitted:
                raise RuntimeError(
                    f"channel {channel} already admitted; submit before serve "
                    "or from the on_barrier hook for late joiners"
                )
            peer.pending.append((elems, cfg or PBSConfig(), d_known))
            peer.d_known.append(d_known)
            return len(peer.pending) - 1

    # -- eviction / retirement -------------------------------------------

    def _evict(self, peer: _Peer, err: BaseException) -> None:
        """Fail one peer: mark its sessions failed (they never plan again),
        retire its channel as stale, and close its transport so a blocked
        peer fails fast instead of hanging."""
        peer.retired = True
        if isinstance(err, TransportError):
            peer.error = err
        else:
            peer.error = TransportError(f"{peer.label}: {err}")
            peer.error.__cause__ = err
        for sess in peer.sessions:
            sess.failed = True
        self.stale_channels.add(peer.channel)
        self._stats["peers_failed"] = self._stats.get("peers_failed", 0) + 1
        try:
            peer.transport.close()
        except Exception:
            pass

    def _finish_peer(self, peer: _Peer, payload: bytes) -> None:
        """The final verification exchange (peer has no live work left)."""
        try:
            ack, flags = verify_ack_entries(payload, peer.sessions)
            peer.tally["verify"] += framed_len(len(payload))
            peer.stream.send(ack)
            peer.tally["verify"] += len(ack)
        except (TransportError, WireError) as e:
            self._evict(peer, e)
            return
        peer.verified = flags
        peer.retired = True
        if not self._continuous:
            # a continuous-sync peer comes back next epoch; only one-shot
            # completion retires the channel id for good
            self.stale_channels.add(peer.channel)

    # -- the shared peer poller -------------------------------------------

    def _poll_peers(self, handlers: dict, phase: str) -> None:
        """Round-robin-poll every peer in ``handlers`` (channel -> frame
        handler) under ONE deadline from call start, so no single silent
        peer can stall the others.  A handler receives each inbound
        (peer, msg_type, payload), returns True when its peer needs no more
        frames, and may raise ``WireError``/``TransportError`` to evict.
        ``TransportTimeout`` on a poll slice keeps waiting; any other
        transport failure evicts immediately; peers still pending when the
        deadline passes with no progress are evicted with a deadline error.
        This one loop carries the straggler semantics of both the admission
        phase and the round barriers (DESIGN.md §10).
        """
        deadline_at = time.monotonic() + self._deadline
        pending = dict(handlers)
        while pending:
            progressed = False
            for ch in list(pending):
                peer = self._peers[ch]
                try:
                    msg_type, payload = peer.stream.recv(timeout=_POLL_S)
                except TransportTimeout:
                    continue
                except (TransportError, WireError) as e:
                    self._evict(peer, e)
                    del pending[ch]
                    continue
                progressed = True
                try:
                    if pending[ch](peer, msg_type, payload):
                        del pending[ch]
                except (TransportError, WireError) as e:
                    self._evict(peer, e)
                    del pending[ch]
            if pending and not progressed and time.monotonic() >= deadline_at:
                for ch in pending:
                    self._evict(self._peers[ch], TransportError(
                        f"{self._peers[ch].label}: no frame within the "
                        f"{self._deadline}s {phase} deadline"
                    ))
                break

    # -- admission (phase 0) ---------------------------------------------

    def _admit(self, rnd: int) -> bool:
        """Admit at round offset ``rnd`` every registered peer that has at
        least one submitted session: pin known-d plans immediately, drive
        the estimator sessions' phase-0 ToW exchanges through the shared
        round-robin poller (one silent joiner cannot stall the others'
        admission past the deadline), then join the survivors' sessions to
        the shared batch.  A peer whose ``submit`` has not landed yet stays
        queued for the next barrier — ``add_peer`` then ``submit`` from
        another thread can never admit a session-less peer by racing the
        barrier.  Returns True iff any peer was admitted."""
        with self._lock:
            joiners = [
                ch for ch in self._joiners if self._peers[ch].pending
            ]
            self._joiners = [ch for ch in self._joiners if ch not in joiners]
            pending_of = {ch: list(self._peers[ch].pending) for ch in joiners}
        if not joiners:
            return False
        plans: dict[int, list] = {}
        est_idx: dict[int, list[int]] = {}      # ch -> indices awaiting ToW
        for ch in joiners:
            peer = self._peers[ch]
            if ch not in self._order:           # re-queued leftover submits
                self._order.append(ch)
                self._stats["peers"] = self._stats.get("peers", 0) + 1
            plans[ch] = [
                None if dk is None else plan_from_d_known(cfg, dk)
                for _, cfg, dk in pending_of[ch]
            ]
            idxs = [i for i, p in enumerate(plans[ch]) if p is None]
            if idxs:
                est_idx[ch] = idxs

        def _phase0_handler(ch):
            def handle(peer, msg_type, payload):
                if msg_type != wf.MSG_TOW_SKETCH:
                    raise WireError(
                        f"expected message 0x{wf.MSG_TOW_SKETCH:02x}, "
                        f"got 0x{msg_type:02x}"
                    )
                idx = est_idx[ch][0]
                set_b, cfg, _ = pending_of[ch][idx]
                reply, plan, est_bytes = serve_phase0(payload, set_b, cfg)
                peer.stream.send(reply)
                peer.tally["estimator"] += est_bytes
                plans[ch][idx] = plan
                est_idx[ch].pop(0)
                return not est_idx[ch]
            return handle

        self._poll_peers(
            {ch: _phase0_handler(ch) for ch in est_idx}, phase="admission"
        )

        for ch in joiners:
            peer = self._peers[ch]
            if peer.retired:
                continue
            new = [
                ReconSession(
                    sid=len(self._sessions) + i,
                    plan=plan,
                    state=new_session_state(_EMPTY, set_b, plan),
                    rnd0=rnd,
                )
                for i, (plan, (set_b, _, _)) in enumerate(
                    zip(plans[ch], pending_of[ch])
                )
            ]
            with self._lock:
                # a submit that raced in after the snapshot stays pending
                # and admits at the next barrier (its own rnd0)
                peer.pending = peer.pending[len(pending_of[ch]):]
                peer.admitted = True
                if peer.pending:
                    self._joiners.append(ch)
            peer.sessions.extend(new)
            self._batch.add_sessions(new)   # appends to self._sessions
        return True

    # -- continuous sync (DESIGN.md §11) ----------------------------------

    def advance_epoch(self, mutations: dict | None = None, *,
                      d_known: dict | None = None) -> int:
        """Open the next epoch for every surviving peer; returns its number.

        ``mutations``: channel -> {local sid: (added, removed)} — this
        side's per-session churn on B (the hub never folds a diff; B is
        the canonical replica its peers converge to).  ``d_known``:
        channel -> {local sid: d | None} *rebinds* a session's d
        convention from this epoch on (an int pins d for this and later
        epochs, ``None`` returns it to estimation); unmentioned sessions
        keep their current convention (initially the submit-time one), so
        estimator sessions re-run the d̂ handshake when their peer opens
        the epoch.
        Evicted peers stay retired; everyone else un-retires and the next
        ``serve`` starts with the ``MSG_EPOCH`` handshake barrier, patches
        the resident stores in place, and drives the epoch's rounds.
        Requires ``HubEndpoint(continuous=True)``.
        """
        if not self._continuous:
            raise RuntimeError("advance_epoch needs HubEndpoint(continuous=True)")
        if self._epoch_open:
            raise RuntimeError(
                f"epoch {self._epoch} is already staged; serve it first"
            )
        muts = mutations or {}
        dks = d_known or {}
        # a typo'd channel or local sid must not silently drop churn
        for name, by_ch in (("mutations", muts), ("d_known", dks)):
            for ch, per_sid in by_ch.items():
                if ch not in self._peers:
                    raise KeyError(f"unknown channel {ch} in epoch {name}")
                bad = set(per_sid or {}) - set(
                    range(len(self._peers[ch].sessions))
                )
                if bad:
                    raise KeyError(
                        f"unknown sid(s) {sorted(bad)} for channel {ch} "
                        f"in epoch {name}"
                    )
        self._epoch += 1
        self._epoch_open = True
        for ch in self._order:
            peer = self._peers[ch]
            if peer.error is not None:
                continue                    # evicted peers never come back
            for i, dk in (dks.get(ch) or {}).items():
                peer.d_known[i] = dk
            pend = {}
            for i, sess in enumerate(peer.sessions):
                added, removed = (muts.get(ch) or {}).get(i, (_EMPTY, _EMPTY))
                pend[i] = (
                    apply_churn(sess.state.b, added, removed),
                    peer.d_known[i],
                )
            peer.epoch_pending = pend
            peer.epoch_plans = {}
            peer.retired = False
            peer.verified = None
        return self._epoch

    def _epoch_handshake(self) -> None:
        """The epoch-open barrier: every surviving peer owes its
        ``MSG_EPOCH`` frames — one wrapped ToW sketch per estimator
        session (answered with a wrapped d̂ reply through the shared
        ``serve_phase0``), or a single bare epoch-open when the peer has
        none — under the usual per-peer deadline; a silent peer is evicted
        here exactly like at a round barrier.  Survivors' sessions then
        fold the epoch in: fresh plans and round states, resident stores
        delta-patched in place (zero rebuilds on the pure delta path).
        """
        self._epoch_open = False
        active = [
            self._peers[ch] for ch in self._order
            if not self._peers[ch].retired and self._peers[ch].epoch_pending
        ]

        def _handler(ch):
            def handle(peer, msg_type, payload):
                if msg_type != wf.MSG_EPOCH:
                    raise WireError(
                        f"expected message 0x{wf.MSG_EPOCH:02x}, "
                        f"got 0x{msg_type:02x}"
                    )
                return serve_epoch_frame(
                    payload, self._epoch, peer.epoch_pending,
                    peer.epoch_plans,
                    lambda i: peer.sessions[i].plan.cfg,
                    peer.stream, peer.tally,
                )
            return handle

        self._poll_peers(
            {p.channel: _handler(p.channel) for p in active},
            phase="epoch-handshake",
        )
        for peer in active:
            if peer.retired:                # evicted during the handshake
                peer.epoch_pending = None
                continue
            pend, peer.epoch_pending = peer.epoch_pending, None
            for i in sorted(pend):
                set_b, dk = pend[i]
                sess = peer.sessions[i]
                plan = peer.epoch_plans.get(i) or plan_from_d_known(
                    sess.plan.cfg, dk
                )
                advance_session(self._batch, sess, plan, new_b=set_b, rnd0=0)
            peer.epoch_plans = {}

    # -- the round barrier ------------------------------------------------

    def _collect(self, expect: dict[int, int]) -> dict[int, bytes]:
        """One frame from each peer in ``expect`` (channel -> msg type) via
        the shared poller; timed-out, disconnected, or misbehaving peers
        are evicted and simply absent from the result."""
        got: dict[int, bytes] = {}

        def _handler(ch, want):
            def handle(peer, msg_type, payload):
                if msg_type != want:
                    raise WireError(
                        f"expected message 0x{want:02x}, got 0x{msg_type:02x}"
                    )
                got[ch] = payload
                return True
            return handle

        self._poll_peers(
            {ch: _handler(ch, want) for ch, want in expect.items()},
            phase="round-barrier",
        )
        return got

    def _peer_live(self, peer: _Peer, rnd: int) -> bool:
        """Mirror of the peer's own ``plan_round(local) != []`` check."""
        return any(
            not s.failed and session_live(s.state, s.plan.cfg, rnd - s.rnd0)
            for s in peer.sessions
        )

    # -- serve -------------------------------------------------------------

    def serve(self) -> dict[int, PeerOutcome]:
        """Drive every peer's sessions to completion; channel -> outcome."""
        st = self._stats = {
            "epoch": self._epoch,
            "rounds": 0, "cohort_rounds": 0,
            "kernel_launches": 0, "decode_launches": 0,
            "h2d_round_bytes": 0,
            "peers": self._stats.get("peers", 0),
            "peers_failed": self._stats.get("peers_failed", 0),
        }
        prior = self._batch.counters()
        retrace_mark = retrace_count()
        rnd = 0
        hook_fired_at = -1
        if self._epoch_open:
            self._epoch_handshake()
        self._admit(rnd)
        while True:
            active = [
                self._peers[ch] for ch in self._order
                if not self._peers[ch].retired
            ]
            if not active:
                # fire the barrier hook at most once per round number, even
                # when the round-end firing below already covered this rnd
                if self.on_barrier is not None and hook_fired_at != rnd:
                    hook_fired_at = rnd
                    self.on_barrier(rnd)
                if not self._admit(rnd):
                    break
                continue
            rnd += 1

            # barrier phase 1: live peers owe ROUND_SKETCHES, finished
            # peers owe VERIFY — collect both in one round-robin sweep
            expect = {
                p.channel: (
                    wf.MSG_ROUND_SKETCHES if self._peer_live(p, rnd)
                    else wf.MSG_VERIFY
                )
                for p in active
            }
            frames = self._collect(expect)
            for ch, payload in list(frames.items()):
                if expect[ch] == wf.MSG_VERIFY:
                    self._finish_peer(self._peers[ch], payload)
                    del frames[ch]

            # shared plan over every surviving live session (evictions
            # above already marked their sessions failed), then the fused
            # single-side encode: 2 kernel launches per cohort, all peers
            plans = self._batch.plan_round(rnd)
            # launch counters are bumped at the dispatch sites inside the
            # helpers, so the fusion stats measure dispatches — one encode
            # and one decode per cohort regardless of peer count — rather
            # than echoing the planner's own bookkeeping
            per = encode_round_rows(plans, self.side, self._interpret,
                                    launches=st)
            if plans:
                st["rounds"] = rnd
            st["cohort_rounds"] += len(plans)
            st["h2d_round_bytes"] += sum(p.h2d_bytes for p in plans)

            round_ctx = self._apply_sketches(rnd, frames, plans, per)

            # barrier phase 2: the per-peer checksum-outcome frames
            outcomes = self._collect({
                ch: wf.MSG_ROUND_OUTCOME for ch in round_ctx
            })
            for ch, payload in outcomes.items():
                self._apply_outcome(self._peers[ch], rnd, payload,
                                    *round_ctx[ch])

            if self.on_barrier is not None:
                hook_fired_at = rnd
                self.on_barrier(rnd)
            self._admit(rnd)

        st["store_uploads"] = self._batch.store_builds
        # per-serve continuous-sync ledger: store uploads, rebuilds, and
        # delta-patch bytes THIS epoch paid for (DESIGN.md §11) — a
        # zero-rebuild epoch shows store_builds == 0, zero store bytes,
        # and only O(churn) delta bytes (store_uploads stays cumulative:
        # the one-per-cohort fusion contract the acceptance test asserts)
        delta = {
            k: v - prior[k] for k, v in self._batch.counters().items()
        }
        st["h2d_store_bytes"] = delta["store_build_bytes"]
        st["store_builds"] = delta["store_builds"]
        st["store_compactions"] = delta["store_compactions"]
        st["h2d_delta_bytes"] = delta["store_delta_bytes"]
        st["h2d_bytes"] = (
            st["h2d_store_bytes"] + st["h2d_round_bytes"]
            + st["h2d_delta_bytes"]
        )
        # jit cache-miss ledger (DESIGN.md §12): compilations THIS serve
        # triggered across every kernel entry point — a warm hub epoch
        # re-uses the pow2-bucketed signatures and reports 0
        st["retraces"] = retrace_count() - retrace_mark
        return {
            ch: PeerOutcome(
                channel=ch,
                ok=self._peers[ch].error is None,
                verified=self._peers[ch].verified,
                error=self._peers[ch].error,
                sessions=self._peers[ch].sessions,
                wire_stats=self._peers[ch].wire_stats(),
            )
            for ch in self._order
        }

    @property
    def stats(self) -> dict:
        """Fusion ledger of the last ``serve``: global rounds, cohort
        rounds, kernel/decode launches (2 + 1 per cohort-round, shared
        across all peers), and the store-upload accounting."""
        return dict(self._stats)

    # -- round internals ---------------------------------------------------

    def _apply_sketches(self, rnd: int, frames: dict[int, bytes], plans, per):
        """Decode every peer's sketch frame against its schema, run ONE
        batched BCH decode per cohort across all peers' units, and send
        each surviving peer its reply frame.  Returns the per-peer outcome
        context for barrier phase 2."""
        # per peer: her live sessions in local-sid order + decoded blocks
        sk_a_of: dict[int, np.ndarray] = {}     # global sid -> (U, t)
        peer_live: dict[int, list[int]] = {}    # channel -> global sids
        for ch, payload in frames.items():
            peer = self._peers[ch]
            live_g = [s.sid for s in peer.sessions if s.sid in per]
            try:
                got_rnd, blocks = wf.decode_round_sketches(
                    payload, round_schema(per, live_g)
                )
                local = rnd - (peer.sessions[0].rnd0 if peer.sessions else 0)
                if got_rnd != local:
                    raise WireError(
                        f"sketch frame for round {got_rnd}, expected {local}"
                    )
            except WireError as e:
                self._evict(peer, e)
                continue
            peer.tally["protocol"] += framed_len(len(payload))
            peer_live[ch] = live_g
            sk_a_of.update(zip(live_g, blocks))

        # one decode launch per cohort, all peers' units stacked; sessions
        # of peers evicted after planning keep zero rows and are skipped
        results, ctx = decode_side_b_round(plans, per, sk_a_of,
                                           launches=self._stats)

        round_ctx: dict[int, tuple] = {}
        for ch, live_g in peer_live.items():
            peer = self._peers[ch]
            local = rnd - (peer.sessions[0].rnd0 if peer.sessions else 0)
            reply = wf.encode_round_reply(
                local, [results[g] for g in live_g], round_schema(per, live_g)
            )
            try:
                peer.stream.send(reply)
            except TransportError as e:
                self._evict(peer, e)
                continue
            peer.tally["protocol"] += len(reply)
            round_ctx[ch] = (live_g, ctx)
        return round_ctx

    def _apply_outcome(self, peer: _Peer, rnd: int, payload: bytes,
                       live_g: list[int], ctx: dict[int, tuple]) -> None:
        """Mirror one peer's unit-queue evolution from her outcome frame:
        our decode failures drive the same deterministic 3-way split, her
        flags settle the checksums we cannot compute (we never see A)."""
        try:
            got_rnd, done_lists = wf.decode_round_outcome(
                payload, [len(ctx[g][1]) for g in live_g]
            )
            local = rnd - (peer.sessions[0].rnd0 if peer.sessions else 0)
            if got_rnd != local:
                raise WireError(
                    f"outcome frame for round {got_rnd}, expected {local}"
                )
        except WireError as e:
            self._evict(peer, e)
            return
        peer.tally["protocol"] += framed_len(len(payload))
        for g, done in zip(live_g, done_lists):
            sess, active, ok, _ = ctx[g]
            local = rnd - sess.rnd0
            for slot, u in enumerate(active):
                if not ok[slot]:
                    queue_split(sess.state, u, local, sess.plan.cfg.seed)
                elif done[slot]:
                    u.done = True
            sess.state.rounds = local


def _drive_hub(
    hub: HubEndpoint,
    peer_calls: dict[int, object],
    join_timeout: float,
):
    """Run one hub ``serve`` against one callable per peer channel."""
    results: dict[int, dict[int, ReconcileResult]] = {}
    errors: dict[int, BaseException] = {}

    def _drive(ch: int, call):
        try:
            results[ch] = call()
        except BaseException as e:  # noqa: BLE001 - reported per peer
            errors[ch] = e

    threads = [
        threading.Thread(target=_drive, args=(ch, call),
                         name=f"peer-{ch}", daemon=True)
        for ch, call in peer_calls.items()
    ]
    for th in threads:
        th.start()
    outcomes = hub.serve()
    for th in threads:
        th.join(timeout=join_timeout)
    return outcomes, results, errors


def run_hub(
    hub: HubEndpoint,
    alices: dict[int, AliceEndpoint],
    *,
    join_timeout: float = 120.0,
):
    """Drive a hub and its connected peers concurrently: each Alice on a
    worker thread, the hub on the caller's thread.

    Returns ``(outcomes, results, errors)``: the hub's per-channel
    ``PeerOutcome``s, per-channel Alice results (``sid -> ReconcileResult``)
    for peers whose ``run`` completed, and per-channel exceptions for peers
    whose ``run`` raised (evicted stragglers see their transport closed, so
    they fail fast with ``TransportError`` instead of hanging).
    """
    return _drive_hub(
        hub, {ch: ep.run for ch, ep in alices.items()}, join_timeout
    )


def run_hub_epoch(
    hub: HubEndpoint,
    alices: dict[int, AliceEndpoint],
    *,
    join_timeout: float = 120.0,
):
    """Drive one staged continuous-sync epoch (DESIGN.md §11): the hub and
    every surviving peer must have called ``advance_epoch``; each Alice
    runs ``run_epoch`` on a worker thread against one hub ``serve``.  Same
    return shape and per-peer error semantics as ``run_hub``.
    """
    return _drive_hub(
        hub, {ch: ep.run_epoch for ch, ep in alices.items()}, join_timeout
    )
