"""Multi-peer reconciliation hub: one endpoint serving N peers (DESIGN.md §10).

``HubEndpoint`` is the serving (Bob) side of N concurrent PBS sessions'
worth of peers: every peer connects over its own ``Transport``, is assigned
a **channel id**, and exchanges ``repro.wire`` frames wrapped in the
``MSG_MUX`` envelope tagged with that id — a frame carrying any other id
(unknown, stale, zero, or unwrapped) is rejected and fails only that peer.
Peers run stock ``AliceEndpoint``s constructed with ``channel=``; their
protocol, ledgers, and results are byte-identical to the pair path.

The point of the hub is *fusion*: all peers' sessions feed **one shared**
``SessionBatch(sides=("b",))``, so a global round packs every peer's active
units into the same per-code cohorts — one ``encode_side`` (one
``bin_parity_xorsum_units`` launch + one GF(2) sketch matmul) and one
``bch_decode_batched`` launch per cohort, shared across all N peers,
instead of N independent pipelines.

Scenario diversity the pair path never sees (all exercised in
tests/test_hub.py and tests/test_protocol_conformance.py):

* **peers joining between global rounds** — a session admitted after global
  round k carries ``rnd0 = k``; all protocol-visible round arithmetic (bin
  seeds, budget, frame round numbers) uses its *local* round, so a late
  joiner is byte-identical to a pair that started alone;
* **stragglers** — the round barrier polls every peer with a per-peer
  deadline from barrier start; a peer whose frame does not arrive in time
  is evicted (its sessions fail with the deadline ``TransportError``) and
  the round proceeds with the survivors;
* **mid-protocol disconnect** — any non-timeout transport failure or
  malformed frame evicts just that peer, surfacing as a clean per-peer
  error in its ``PeerOutcome`` while every other peer completes untouched;
* **mixed known-d and estimator peers** — estimator sessions run their
  phase-0 ToW exchange at admission, then share cohorts with known-d
  sessions as usual;
* **continuous epochs** (``continuous=True``, DESIGN.md §11) — after every
  peer's epoch settles, ``advance_epoch`` stages each side's churn, the
  next ``serve`` opens with a ``MSG_EPOCH`` handshake barrier (epoch id +
  per-estimator-session d̂ re-estimation), and the shared cohort stores
  take an in-place O(churn) delta patch instead of a rebuild — sessions,
  channels, and device residency all survive across epochs
  (tests/test_sync_churn.py soaks ≥20 epochs against the oracle).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.tow import ESTIMATE_LIMIT_FRAC, EstimateOutOfRange
from repro.core.pbs import (
    MAX_PARITY_EXTENSIONS,
    PBSConfig,
    ReconcileResult,
    new_session_state,
    parity_extension_t,
    plan_from_d_known,
    queue_split,
    session_live,
)
from repro.kernels.ops import bch_decode_batched
from repro.recon.session import (
    ReconSession,
    SessionBatch,
    advance_session,
    apply_churn,
    degrade_exhausted,
)
from repro.kernels.platform import (
    enable_persistent_cache,
    retrace_count,
    retrace_counts,
)
from repro.obs import NULL_TRACER, Recorder
from repro.wire import frames as wf
from repro.wire.frames import ReplyUnit, WireError
from repro.wire.varint import framed_len

from repro.tree.partition import TreeConfig, leaf_slices

from .endpoint import (
    AliceEndpoint,
    decode_side_b_round,
    encode_round_rows,
    encode_round_rows_ext,
    round_schema,
    serve_epoch_frame,
    serve_phase0,
    serve_tree_frame,
    stream_wire_stats,
    tree_walk_state,
    verify_ack_entries,
)
from .resilience import PeerDeadline, classify_error
from .transport import FrameStream, Transport, TransportError, TransportTimeout

_EMPTY = np.zeros(0, dtype=np.uint32)
_POLL_S = 0.02  # barrier round-robin slice: bounds one sweep over N peers


@dataclass
class PeerOutcome:
    """One peer's final disposition after ``serve``."""

    channel: int
    ok: bool                            # verify exchange completed
    verified: list[bool] | None         # per-session verdicts (ok peers)
    error: BaseException | None         # eviction cause (failed peers)
    sessions: list[ReconSession]        # the hub's mirrored session states
    wire_stats: dict
    # typed failure taxonomy (DESIGN.md §13): "deadline" / "estimate" /
    # "wire" / "transport" / "error" for failed peers; "resumed" /
    # "degraded" for ok peers that took the recovery paths; None for a
    # clean untouched run
    error_kind: str | None = None
    # tree front end (§15): deepest level the peer's walk reached and the
    # leaf sessions it admitted; (0, None) for peers that ran no tree phase
    tree_depth: int = 0
    tree_leaves: int | None = None


class _Peer:
    """Hub-side connection state for one channel."""

    def __init__(self, channel: int, transport: Transport, label: str | None):
        self.channel = channel
        self.label = label or f"peer{channel}"
        self.transport = transport
        self.stream = FrameStream(transport, channel=channel)
        self.pending: list[tuple] = []      # (set_b, cfg, d_known) pre-admission
        self.sessions: list[ReconSession] = []  # local-sid order
        self.admitted = False
        self.retired = False
        self.verified: list[bool] | None = None
        self.error: BaseException | None = None
        self.tally = {
            "estimator": 0, "protocol": 0, "verify": 0, "epoch": 0,
            "resume": 0, "tree": 0,
        }
        self.d_known: list[int | None] = []     # per local sid, epoch default
        # tree front end (§15): staged (set_b, cfg, tcfg) awaiting the
        # walk, the in-flight walk state, and the outcome summary
        self.tree_pending: tuple | None = None
        self.tree_walk: dict | None = None
        self.tree_depth = 0
        self.tree_leaves: int | None = None
        self.epoch_pending: dict[int, tuple] | None = None  # sid -> (set_b, dk)
        self.epoch_plans: dict[int, object] = {}
        # -- resumption record (DESIGN.md §13), bounded: one retained round
        # context + two 64-bit digests + the frame-numbering offset
        self.rnd0 = 0                   # global round of this peer's admission
        self.rounds_done = 0            # local barriers applied (peer's clock)
        # the hub's global epoch at this peer's admission: a mid-life
        # joiner (tree cold start, §15) opens at local epoch 0 while the
        # hub's counter is already at E — every protocol-visible epoch for
        # this peer (MSG_EPOCH ids, transcript seeds, resume frames) is
        # the local ``hub epoch - epoch_base``
        self.epoch_base = 0
        self.digest = wf.transcript_digest0(0)
        self.digest_prev = self.digest
        self.inflight_ctx: tuple | None = None  # (live_g, ctx) awaiting outcome
        self.suspended = False
        self.suspend_at = 0.0           # monotonic expiry of the resume window
        self.suspend_err: BaseException | None = None
        self.resumes = 0
        self.marks = {"protocol": 0, "verify": 0}   # tallies at last barrier
        self.carry: dict = {}           # totals of resumed-away transports
        # per-peer registry: wire_stats routes through it so every key is
        # schema-declared and the dict is a derived snapshot (DESIGN.md §14)
        self.recorder = Recorder()

    def wire_stats(self) -> dict:
        self.recorder.publish(
            "wire", stream_wire_stats(self.stream, self.tally, self.carry)
        )
        return self.recorder.view("wire")


class HubEndpoint:
    """One serving endpoint reconciling against N peers concurrently.

    Usage::

        hub = HubEndpoint()
        ch = hub.add_peer(transport)          # one Transport per peer
        hub.submit(ch, set_b, cfg=cfg, d_known=d)   # positional, like a pair
        outcomes = hub.serve()                # dict channel -> PeerOutcome

    ``add_peer``/``submit`` may also be called while ``serve`` runs (from
    another thread, or from the ``on_barrier`` hook): the peer is admitted
    at the next global-round barrier with ``rnd0`` = the completed round.
    ``recv_deadline`` is the per-peer barrier deadline; ``on_barrier`` (if
    set) is called with the just-completed global round number — the
    deterministic injection point tests use for mid-run joins.
    """

    side = "b"

    def __init__(
        self,
        *,
        interpret: bool | None = None,
        recv_deadline: float = 60.0,
        on_barrier=None,
        continuous: bool = False,
        resume_window: float = 0.0,
        degrade: bool = False,
        estimate_limit: float | None = ESTIMATE_LIMIT_FRAC,
        recorder: Recorder | None = None,
        tracer=None,
    ):
        enable_persistent_cache()
        self._interpret = interpret
        # telemetry (DESIGN.md §14): the `stats` view derives from the
        # recorder's hub.* rows; every barrier/eviction/resume goes through
        # the tracer (NULL_TRACER = disabled, free)
        self.recorder = recorder if recorder is not None else Recorder()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._deadline = recv_deadline
        self.on_barrier = on_barrier
        self._continuous = continuous
        # resume_window > 0 turns mid-round transport failures of admitted
        # peers into *suspensions* (DESIGN.md §13): the peer's sessions and
        # store rows stay resident and ``resume_peer`` may re-attach it for
        # that many seconds before the suspension hardens into an eviction.
        # 0 keeps the historical evict-immediately behavior.
        self._resume_window = resume_window
        # degrade=True escalates decode-budget-exhausted sessions (doubled
        # d̂ re-plan, counted in ``sessions_degraded``) instead of letting
        # them run out the round budget into ``failed=True``; peers must
        # run matching ``degrade=True`` endpoints.
        self._degrade = degrade
        # phase-0 operating-regime guard (§15): a joiner whose planned d̂
        # exceeds this fraction of |A| + |B| is evicted with
        # error_kind="estimate" (the pair belongs to the tree front end);
        # None restores the unguarded legacy behaviour
        self._estimate_limit = estimate_limit
        self._lock = threading.Lock()
        self._peers: dict[int, _Peer] = {}
        self._order: list[int] = []         # admission order of channels
        self._joiners: list[int] = []       # added but not yet admitted
        self._next_channel = 1
        self.stale_channels: set[int] = set()
        self._sessions: list[ReconSession] = []
        self._batch = SessionBatch(
            self._sessions, sides=(self.side,), mutable=continuous,
            tracer=self.tracer,
        )
        self._stats: dict = {}
        self._epoch = 0
        self._epoch_open = False
        self._rnd = 0               # current global round (serve loop clock)

    # -- registration ----------------------------------------------------

    def add_peer(self, transport: Transport, *, label: str | None = None) -> int:
        """Register a peer connection; returns its channel id (never 0,
        never reused — a retired channel's id stays stale forever)."""
        with self._lock:
            ch = self._next_channel
            self._next_channel += 1
            self._peers[ch] = _Peer(ch, transport, label)
            self._joiners.append(ch)
        return ch

    def submit(
        self,
        channel: int,
        set_b,
        cfg: PBSConfig | None = None,
        d_known: int | None = None,
    ) -> int:
        """Enqueue this hub's side of the peer's next session (positional
        pairing with the peer's ``submit`` order, like the pair path);
        returns the peer-local sid.  Must precede the peer's admission."""
        peer = self._peers[channel]
        elems = np.unique(np.asarray(set_b, dtype=np.uint32))
        with self._lock:
            if peer.admitted:
                raise RuntimeError(
                    f"channel {channel} already admitted; submit before serve "
                    "or from the on_barrier hook for late joiners"
                )
            peer.pending.append((elems, cfg or PBSConfig(), d_known))
            peer.d_known.append(d_known)
            return len(peer.pending) - 1

    def submit_tree(
        self,
        channel: int,
        set_b,
        cfg: PBSConfig | None = None,
        tree: TreeConfig | None = None,
    ) -> None:
        """Stage the hub's side of the peer's tree-phase cold start (§15):
        the walk runs at the peer's admission, before phase 0, under the
        same per-peer deadline — a peer that goes silent mid-walk is
        evicted cleanly (nothing was admitted yet) and may reconnect and
        re-stage from scratch.  Every divergent leaf range becomes an
        ordinary known-d session appended after the peer's regular
        ``submit``s; the peer must ``submit_tree`` its matching side with
        the same ``cfg``/``tree`` (positional contract)."""
        peer = self._peers[channel]
        with self._lock:
            if peer.admitted:
                raise RuntimeError(
                    f"channel {channel} already admitted; stage the tree "
                    "before serve"
                )
            if peer.tree_pending is not None or peer.tree_walk is not None:
                raise RuntimeError(
                    f"channel {channel} already has a tree phase staged"
                )
            peer.tree_pending = (
                np.unique(np.asarray(set_b, dtype=np.uint32)),
                cfg or PBSConfig(),
                tree or TreeConfig(),
            )

    # -- eviction / retirement -------------------------------------------

    def _evict(self, peer: _Peer, err: BaseException) -> None:
        """Fail one peer: mark its sessions failed (they never plan again),
        retire its channel as stale, and close its transport so a blocked
        peer fails fast instead of hanging."""
        peer.retired = True
        peer.suspended = False
        if isinstance(err, TransportError):
            peer.error = err
        else:
            peer.error = TransportError(f"{peer.label}: {err}")
            peer.error.__cause__ = err
        for sess in peer.sessions:
            sess.failed = True
            sess.suspended = False
        self.stale_channels.add(peer.channel)
        self._stats["peers_failed"] = self._stats.get("peers_failed", 0) + 1
        kind = classify_error(peer.error)
        by_kind = self._stats.setdefault("peers_failed_by_kind", {})
        by_kind[kind] = by_kind.get(kind, 0) + 1
        self.tracer.instant("peer.evict", channel=peer.channel,
                            peer=peer.label, kind=kind)
        try:
            peer.transport.close()
        except Exception:
            pass

    def _fail(self, peer: _Peer, err: BaseException, *, resumable: bool) -> None:
        """Route one peer failure: a transport-level failure of an admitted,
        mid-round peer suspends (resumable, DESIGN.md §13) when a resume
        window is configured; protocol violations (``WireError``) and
        pre-admission failures always evict permanently."""
        if (
            resumable
            and self._resume_window > 0.0
            and peer.admitted
            and isinstance(err, TransportError)
        ):
            self._suspend(peer, err)
        else:
            self._evict(peer, err)

    def _suspend(self, peer: _Peer, err: BaseException) -> None:
        """Park one peer in the resumable state: its sessions stop planning
        (``suspended``, NOT ``failed`` — cohort-store membership survives,
        so resumption rebuilds nothing), its channel stays valid, and the
        recovery record (``rounds_done``/``digest``/``inflight_ctx``) waits
        for ``resume_peer`` until the resume window expires."""
        peer.retired = True
        peer.suspended = True
        peer.suspend_err = err
        peer.suspend_at = time.monotonic() + self._resume_window
        self.tracer.instant("peer.suspend", channel=peer.channel,
                            peer=peer.label, barrier=peer.rounds_done)
        for sess in peer.sessions:
            sess.suspended = True
        try:
            peer.transport.close()
        except Exception:
            pass

    def _expire_overdue(self) -> None:
        """Harden every suspension whose resume window has lapsed into a
        permanent eviction carrying the original failure as its cause."""
        now = time.monotonic()
        for peer in self._peers.values():
            if not peer.suspended or now < peer.suspend_at:
                continue
            cause = peer.suspend_err
            err = type(cause)(
                f"{peer.label}: resume window ({self._resume_window}s) "
                "expired"
            ) if isinstance(cause, TransportError) else TransportError(
                f"{peer.label}: resume window expired"
            )
            err.__cause__ = cause
            self._evict(peer, err)

    # -- resumption (DESIGN.md §13) ----------------------------------------

    def resume_peer(
        self,
        channel: int,
        transport: Transport,
        *,
        timeout: float | None = None,
    ) -> None:
        """Re-attach a suspended peer over a fresh transport.

        Call while ``serve`` is between barriers (the ``on_barrier`` hook is
        the deterministic spot) with the hub side of the peer's replacement
        connection; the peer drives ``AliceEndpoint.resume`` concurrently.
        Runs the ``MSG_RESUME`` handshake against the peer's recovery
        record: equal barriers must agree on ``digest``; a peer exactly one
        barrier ahead (her outcome frame died in flight) must agree on
        ``digest_prev`` and replays that one frame, applied idempotently
        from the retained round context and ledgered as
        ``resume_replay_bytes`` (transport overhead — never Formula-(1)
        bits).  The peer's sessions then re-bind at the current global
        round via an ``rnd0`` shift — no re-admission, no store rebuild —
        and the next barrier serves her like any live peer.  A failed
        handshake (divergent transcript, wrong epoch, dead transport)
        hardens the suspension into a permanent eviction and re-raises.
        """
        peer = self._peers.get(channel)
        if peer is None:
            raise KeyError(f"unknown channel {channel}")
        with self.tracer.span("peer.resume", channel=channel,
                              peer=peer.label, barrier=peer.rounds_done):
            self._resume_peer(peer, channel, transport, timeout)

    def _resume_peer(
        self,
        peer: _Peer,
        channel: int,
        transport: Transport,
        timeout: float | None,
    ) -> None:
        with self._lock:
            if not peer.suspended:
                raise RuntimeError(
                    f"channel {channel} is not suspended (nothing to resume)"
                )
        old = peer.stream
        t_old = old.transport
        peer.carry = {
            "transport_bytes_out": t_old.bytes_out
            + peer.carry.get("transport_bytes_out", 0),
            "transport_bytes_in": t_old.bytes_in
            + peer.carry.get("transport_bytes_in", 0),
            "retransmits": getattr(t_old, "retransmits", 0)
            + peer.carry.get("retransmits", 0),
        }
        stream = FrameStream(transport, channel=channel)
        stream.frames_out, stream.frames_in = old.frames_out, old.frames_in
        stream.bytes_out, stream.bytes_in = old.bytes_out, old.bytes_in
        stream.mux_bytes_out = old.mux_bytes_out
        stream.mux_bytes_in = old.mux_bytes_in
        peer.transport = transport
        peer.stream = stream
        wait = self._deadline if timeout is None else timeout
        try:
            msg_type, payload = stream.recv(timeout=wait)
            if msg_type != wf.MSG_RESUME:
                raise WireError(
                    f"expected message 0x{wf.MSG_RESUME:02x}, "
                    f"got 0x{msg_type:02x}"
                )
            ch, epoch, a_rnd, a_digest, a_digest_prev = wf.decode_resume(
                payload
            )
            if ch != channel or epoch != self._epoch - peer.epoch_base:
                raise WireError(
                    f"resume for channel {ch} epoch {epoch}, expected "
                    f"channel {channel} epoch {self._epoch - peer.epoch_base}"
                )
            replay = False
            if a_rnd == peer.rounds_done:
                if a_digest != peer.digest:
                    raise WireError(
                        "resume transcript diverged at equal barriers"
                    )
                # any in-flight context is from an aborted attempt that
                # will re-run in full — drop it
                peer.inflight_ctx = None
            elif a_rnd == peer.rounds_done + 1 and peer.inflight_ctx:
                if a_digest_prev != peer.digest:
                    raise WireError(
                        "resume transcript diverged one barrier back"
                    )
                replay = True
            else:
                raise WireError(
                    f"unresumable: peer barrier {a_rnd}, "
                    f"ours {peer.rounds_done}"
                )
            reply = wf.encode_resume(
                channel, self._epoch - peer.epoch_base, peer.rounds_done,
                peer.digest, peer.digest_prev,
            )
            stream.send(reply)
            peer.tally["resume"] += framed_len(len(payload)) + len(reply)
            if replay:
                mt, opayload = stream.recv(timeout=wait)
                if mt != wf.MSG_ROUND_OUTCOME:
                    raise WireError(
                        f"expected replayed message "
                        f"0x{wf.MSG_ROUND_OUTCOME:02x}, got 0x{mt:02x}"
                    )
                live_g, ctx = peer.inflight_ctx
                glob = peer.rnd0 + peer.rounds_done + 1
                self._apply_outcome(
                    peer, glob, opayload, live_g, ctx, replay=True
                )
                if peer.error is not None:
                    raise WireError(
                        "replayed outcome frame rejected"
                    ) from peer.error
            else:
                # the aborted partial attempt re-runs: its frame bytes move
                # to the resume tally so Formula-(1) categories count the
                # re-run exactly once (mirrors AliceEndpoint.resume)
                for k, mark in peer.marks.items():
                    spill = peer.tally[k] - mark
                    if spill:
                        peer.tally[k] = mark
                        peer.tally["resume"] += spill
        except (TransportError, WireError) as e:
            if peer.error is None:      # replay rejection already evicted
                self._evict(peer, e)
            raise
        with self._lock:
            # re-bind the peer's local round clock to the hub's: her next
            # local round (rounds_done + 1) must land on the next global
            # round, so every session's rnd0 shifts by the same delta
            # (escalated sessions keep their relative offsets)
            new_rnd0 = self._rnd - peer.rounds_done
            delta = new_rnd0 - peer.rnd0
            for sess in peer.sessions:
                sess.rnd0 += delta
                sess.suspended = False
            peer.rnd0 = new_rnd0
            peer.suspended = False
            peer.retired = False
            peer.suspend_err = None
            peer.resumes += 1
            self._stats["peers_resumed"] = (
                self._stats.get("peers_resumed", 0) + 1
            )

    def _finish_peer(self, peer: _Peer, payload: bytes) -> None:
        """The final verification exchange (peer has no live work left)."""
        try:
            ack, flags = verify_ack_entries(payload, peer.sessions)
            peer.tally["verify"] += framed_len(len(payload))
            peer.stream.send(ack)
            peer.tally["verify"] += len(ack)
        except WireError as e:
            self._evict(peer, e)
            return
        except TransportError as e:
            # ack send died: the exchange is re-runnable after a resume
            # (the peer re-sends MSG_VERIFY; verify_ack_entries is pure)
            self._fail(peer, e, resumable=True)
            return
        peer.marks = {k: peer.tally[k] for k in peer.marks}
        peer.verified = flags
        peer.retired = True
        if not self._continuous:
            # a continuous-sync peer comes back next epoch; only one-shot
            # completion retires the channel id for good
            self.stale_channels.add(peer.channel)

    # -- the shared peer poller -------------------------------------------

    def _poll_peers(self, handlers: dict, phase: str) -> None:
        """Round-robin-poll every peer in ``handlers`` (channel -> frame
        handler) under ONE deadline from call start, so no single silent
        peer can stall the others.  A handler receives each inbound
        (peer, msg_type, payload), returns True when its peer needs no more
        frames, and may raise ``WireError``/``TransportError`` to evict.
        ``TransportTimeout`` on a poll slice keeps waiting; any other
        transport failure evicts immediately; peers still pending when the
        deadline passes with no progress are evicted with a deadline error.
        This one loop carries the straggler semantics of both the admission
        phase and the round barriers (DESIGN.md §10).
        """
        resumable = phase == "round-barrier"
        deadline_at = time.monotonic() + self._deadline
        pending = dict(handlers)
        while pending:
            progressed = False
            for ch in list(pending):
                peer = self._peers[ch]
                try:
                    msg_type, payload = peer.stream.recv(timeout=_POLL_S)
                except TransportTimeout:
                    continue
                except (TransportError, WireError) as e:
                    self._fail(peer, e, resumable=resumable)
                    del pending[ch]
                    continue
                progressed = True
                try:
                    if pending[ch](peer, msg_type, payload):
                        del pending[ch]
                except (EstimateOutOfRange, TransportError, WireError) as e:
                    self._fail(peer, e, resumable=resumable)
                    del pending[ch]
            if pending and not progressed and time.monotonic() >= deadline_at:
                for ch in pending:
                    self._fail(self._peers[ch], PeerDeadline(
                        f"{self._peers[ch].label}: no frame within the "
                        f"{self._deadline}s {phase} deadline"
                    ), resumable=resumable)
                break

    # -- tree front end (DESIGN.md §15) -----------------------------------

    def _tree_handler(self, ch: int):
        """Frame handler driving one tree-staged joiner's walk through the
        shared poller: each inbound digest frame is one level served via
        ``serve_tree_frame``; walk completion appends the leaf sessions to
        the peer's pending queue and returns True."""
        def handle(peer, msg_type, payload):
            if msg_type != wf.MSG_TREE:
                raise WireError(
                    f"expected message 0x{wf.MSG_TREE:02x}, "
                    f"got 0x{msg_type:02x}"
                )
            if peer.tree_walk is None:
                elems, cfg, tcfg = peer.tree_pending
                peer.tree_pending = None
                peer.tree_walk = tree_walk_state(elems, cfg, tcfg)
            w = peer.tree_walk
            if not serve_tree_frame(payload, w, peer.stream, peer.tally,
                                    self.tracer, self._interpret):
                return False
            peer.tree_walk = None
            peer.tree_depth = w["level"] - 1
            peer.tree_leaves = len(w["leaves"])
            with self._lock:
                for sub, leaf in zip(
                    leaf_slices(w["elems"], w["leaves"]), w["leaves"]
                ):
                    peer.pending.append((sub, w["cfg"], leaf.d_plan))
                    peer.d_known.append(leaf.d_plan)
            st = self._stats
            st["tree_levels"] = max(st.get("tree_levels", 0), w["level"])
            st["tree_digest_bytes"] = (
                st.get("tree_digest_bytes", 0) + w["bytes"]
            )
            st["tree_leaves"] = st.get("tree_leaves", 0) + len(w["leaves"])
            self.tracer.instant(
                "peer.tree_done", channel=ch, peer=peer.label,
                levels=w["level"], leaves=len(w["leaves"]), bytes=w["bytes"],
            )
            return True
        return handle

    # -- admission (phase 0) ---------------------------------------------

    def _admit(self, rnd: int) -> bool:
        """Admit at round offset ``rnd`` every registered peer that has at
        least one submitted session: pin known-d plans immediately, drive
        the estimator sessions' phase-0 ToW exchanges through the shared
        round-robin poller (one silent joiner cannot stall the others'
        admission past the deadline), then join the survivors' sessions to
        the shared batch.  A peer whose ``submit`` has not landed yet stays
        queued for the next barrier — ``add_peer`` then ``submit`` from
        another thread can never admit a session-less peer by racing the
        barrier.  Returns True iff any peer was admitted."""
        with self._lock:
            joiners = [
                ch for ch in self._joiners
                if self._peers[ch].pending
                or self._peers[ch].tree_pending is not None
            ]
            self._joiners = [ch for ch in self._joiners if ch not in joiners]
        if not joiners:
            return False
        # tree phase (§15): drive every tree-staged joiner's whole walk —
        # one digest->verdict barrier per level, same deadline semantics —
        # before phase 0; its leaf sessions join the pending queue as
        # known-d submits, appended after the peer's regular ones
        tree_chs = [
            ch for ch in joiners
            if self._peers[ch].tree_pending is not None
        ]
        if tree_chs:
            # a tree-staged joiner enters the protocol here: register it
            # for outcome reporting NOW so a mid-walk eviction still
            # surfaces as a (failed) PeerOutcome instead of vanishing
            with self._lock:
                for ch in tree_chs:
                    if ch not in self._order:
                        self._order.append(ch)
                        self._stats["peers"] = (
                            self._stats.get("peers", 0) + 1
                        )
            with self.tracer.span("hub.tree_phase", peers=len(tree_chs)):
                self._poll_peers(
                    {ch: self._tree_handler(ch) for ch in tree_chs},
                    phase="tree",
                )
            joiners = [ch for ch in joiners if not self._peers[ch].retired]
            if not joiners:
                return False
        with self._lock:
            pending_of = {ch: list(self._peers[ch].pending) for ch in joiners}
        plans: dict[int, list] = {}
        est_idx: dict[int, list[int]] = {}      # ch -> indices awaiting ToW
        for ch in joiners:
            peer = self._peers[ch]
            if ch not in self._order:           # re-queued leftover submits
                self._order.append(ch)
                self._stats["peers"] = self._stats.get("peers", 0) + 1
            plans[ch] = [
                None if dk is None else plan_from_d_known(cfg, dk)
                for _, cfg, dk in pending_of[ch]
            ]
            idxs = [i for i, p in enumerate(plans[ch]) if p is None]
            if idxs:
                est_idx[ch] = idxs

        def _phase0_handler(ch):
            def handle(peer, msg_type, payload):
                if msg_type != wf.MSG_TOW_SKETCH:
                    raise WireError(
                        f"expected message 0x{wf.MSG_TOW_SKETCH:02x}, "
                        f"got 0x{msg_type:02x}"
                    )
                idx = est_idx[ch][0]
                set_b, cfg, _ = pending_of[ch][idx]
                reply, plan, est_bytes = serve_phase0(
                    payload, set_b, cfg, self._estimate_limit
                )
                peer.stream.send(reply)
                peer.tally["estimator"] += est_bytes
                plans[ch][idx] = plan
                est_idx[ch].pop(0)
                return not est_idx[ch]
            return handle

        self._poll_peers(
            {ch: _phase0_handler(ch) for ch in est_idx}, phase="admission"
        )

        for ch in joiners:
            peer = self._peers[ch]
            if peer.retired:
                continue
            new = [
                ReconSession(
                    sid=len(self._sessions) + i,
                    plan=plan,
                    state=new_session_state(_EMPTY, set_b, plan),
                    rnd0=rnd,
                )
                for i, (plan, (set_b, _, _)) in enumerate(
                    zip(plans[ch], pending_of[ch])
                )
            ]
            with self._lock:
                # a submit that raced in after the snapshot stays pending
                # and admits at the next barrier (its own rnd0)
                peer.pending = peer.pending[len(pending_of[ch]):]
                peer.admitted = True
                if peer.pending:
                    self._joiners.append(ch)
            if not peer.sessions:
                # first admission arms the resumption record: the frame
                # numbering base and a transcript opened at this epoch —
                # which is the peer's LOCAL epoch 0 even when the hub's
                # counter is mid-life (tree cold-start joiners, §15)
                peer.rnd0 = rnd
                peer.rounds_done = 0
                peer.epoch_base = self._epoch
                peer.digest = wf.transcript_digest0(0)
                peer.digest_prev = peer.digest
                peer.inflight_ctx = None
                peer.marks = {k: peer.tally[k] for k in peer.marks}
            peer.sessions.extend(new)
            self._batch.add_sessions(new)   # appends to self._sessions
        return True

    # -- continuous sync (DESIGN.md §11) ----------------------------------

    def advance_epoch(self, mutations: dict | None = None, *,
                      d_known: dict | None = None) -> int:
        """Open the next epoch for every surviving peer; returns its number.

        ``mutations``: channel -> {local sid: (added, removed)} — this
        side's per-session churn on B (the hub never folds a diff; B is
        the canonical replica its peers converge to).  ``d_known``:
        channel -> {local sid: d | None} *rebinds* a session's d
        convention from this epoch on (an int pins d for this and later
        epochs, ``None`` returns it to estimation); unmentioned sessions
        keep their current convention (initially the submit-time one), so
        estimator sessions re-run the d̂ handshake when their peer opens
        the epoch.
        Evicted peers stay retired; everyone else un-retires and the next
        ``serve`` starts with the ``MSG_EPOCH`` handshake barrier, patches
        the resident stores in place, and drives the epoch's rounds.
        Requires ``HubEndpoint(continuous=True)``.
        """
        if not self._continuous:
            raise RuntimeError("advance_epoch needs HubEndpoint(continuous=True)")
        if self._epoch_open:
            raise RuntimeError(
                f"epoch {self._epoch} is already staged; serve it first"
            )
        muts = mutations or {}
        dks = d_known or {}
        # a typo'd channel or local sid must not silently drop churn
        for name, by_ch in (("mutations", muts), ("d_known", dks)):
            for ch, per_sid in by_ch.items():
                if ch not in self._peers:
                    raise KeyError(f"unknown channel {ch} in epoch {name}")
                bad = set(per_sid or {}) - set(
                    range(len(self._peers[ch].sessions))
                )
                if bad:
                    raise KeyError(
                        f"unknown sid(s) {sorted(bad)} for channel {ch} "
                        f"in epoch {name}"
                    )
        self._epoch += 1
        self._epoch_open = True
        for ch in self._order:
            peer = self._peers[ch]
            if peer.error is not None:
                continue                    # evicted peers never come back
            for i, dk in (dks.get(ch) or {}).items():
                peer.d_known[i] = dk
            pend = {}
            for i, sess in enumerate(peer.sessions):
                added, removed = (muts.get(ch) or {}).get(i, (_EMPTY, _EMPTY))
                pend[i] = (
                    apply_churn(sess.state.b, added, removed),
                    peer.d_known[i],
                )
            peer.epoch_pending = pend
            peer.epoch_plans = {}
            peer.retired = False
            peer.verified = None
        return self._epoch

    def _epoch_handshake(self) -> None:
        """The epoch-open barrier: every surviving peer owes its
        ``MSG_EPOCH`` frames — one wrapped ToW sketch per estimator
        session (answered with a wrapped d̂ reply through the shared
        ``serve_phase0``), or a single bare epoch-open when the peer has
        none — under the usual per-peer deadline; a silent peer is evicted
        here exactly like at a round barrier.  Survivors' sessions then
        fold the epoch in: fresh plans and round states, resident stores
        delta-patched in place (zero rebuilds on the pure delta path).
        """
        self._epoch_open = False
        active = [
            self._peers[ch] for ch in self._order
            if not self._peers[ch].retired and self._peers[ch].epoch_pending
        ]

        def _handler(ch):
            def handle(peer, msg_type, payload):
                if msg_type != wf.MSG_EPOCH:
                    raise WireError(
                        f"expected message 0x{wf.MSG_EPOCH:02x}, "
                        f"got 0x{msg_type:02x}"
                    )
                return serve_epoch_frame(
                    payload, self._epoch - peer.epoch_base,
                    peer.epoch_pending,
                    peer.epoch_plans,
                    lambda i: peer.sessions[i].plan.cfg,
                    peer.stream, peer.tally, self._estimate_limit,
                )
            return handle

        self._poll_peers(
            {p.channel: _handler(p.channel) for p in active},
            phase="epoch-handshake",
        )
        for peer in active:
            if peer.retired:                # evicted during the handshake
                peer.epoch_pending = None
                continue
            pend, peer.epoch_pending = peer.epoch_pending, None
            for i in sorted(pend):
                set_b, dk = pend[i]
                sess = peer.sessions[i]
                plan = peer.epoch_plans.get(i) or plan_from_d_known(
                    sess.plan.cfg, dk
                )
                advance_session(self._batch, sess, plan, new_b=set_b, rnd0=0)
            peer.epoch_plans = {}
            # re-arm the resumption record for the fresh epoch, mirroring
            # the peer endpoint's _reset_rounds (rnd0 back to 0)
            peer.rnd0 = 0
            peer.rounds_done = 0
            peer.digest = wf.transcript_digest0(self._epoch - peer.epoch_base)
            peer.digest_prev = peer.digest
            peer.inflight_ctx = None
            peer.marks = {k: peer.tally[k] for k in peer.marks}

    # -- the round barrier ------------------------------------------------

    def _collect(self, expect: dict[int, int]) -> dict[int, bytes]:
        """One frame from each peer in ``expect`` (channel -> msg type) via
        the shared poller; timed-out, disconnected, or misbehaving peers
        are evicted and simply absent from the result."""
        got: dict[int, bytes] = {}

        def _handler(ch, want):
            def handle(peer, msg_type, payload):
                if msg_type != want:
                    raise WireError(
                        f"expected message 0x{want:02x}, got 0x{msg_type:02x}"
                    )
                got[ch] = payload
                return True
            return handle

        self._poll_peers(
            {ch: _handler(ch, want) for ch, want in expect.items()},
            phase="round-barrier",
        )
        return got

    def _peer_live(self, peer: _Peer, rnd: int) -> bool:
        """Mirror of the peer's own ``plan_round(local) != []`` check."""
        return any(
            not s.failed and session_live(s.state, s.plan.cfg, rnd - s.rnd0)
            for s in peer.sessions
        )

    # -- serve -------------------------------------------------------------

    def serve(self) -> dict[int, PeerOutcome]:
        """Drive every peer's sessions to completion; channel -> outcome."""
        st = self._stats = {
            "epoch": self._epoch,
            "rounds": 0, "cohort_rounds": 0,
            "kernel_launches": 0, "decode_launches": 0,
            "h2d_round_bytes": 0,
            "peers": self._stats.get("peers", 0),
            "peers_failed": self._stats.get("peers_failed", 0),
            "peers_failed_by_kind": self._stats.get("peers_failed_by_kind", {}),
            "peers_resumed": self._stats.get("peers_resumed", 0),
            "resume_replay_bytes": self._stats.get("resume_replay_bytes", 0),
            "sessions_degraded": self._stats.get("sessions_degraded", 0),
            "parity_extensions": self._stats.get("parity_extensions", 0),
            "tree_levels": 0, "tree_digest_bytes": 0, "tree_leaves": 0,
        }
        prior = self._batch.counters()
        retrace_mark = retrace_count()
        rnd = self._rnd = 0
        hook_fired_at = -1
        tracer = self.tracer
        tracer.instant("hub.serve", epoch=self._epoch)
        if self._epoch_open:
            with tracer.span("hub.epoch_handshake", epoch=self._epoch):
                self._epoch_handshake()
        self._admit(rnd)
        while True:
            self._expire_overdue()
            active = [
                self._peers[ch] for ch in self._order
                if not self._peers[ch].retired
            ]
            if not active:
                suspended = any(
                    p.suspended for p in self._peers.values()
                )
                # fire the barrier hook at most once per round number, even
                # when the round-end firing below already covered this rnd —
                # UNLESS suspended peers are waiting, in which case it
                # re-fires each wait slice so a driver can resume them
                if self.on_barrier is not None and (
                    hook_fired_at != rnd or suspended
                ):
                    hook_fired_at = rnd
                    self.on_barrier(rnd)
                if self._admit(rnd):
                    continue
                if any(
                    not self._peers[ch].retired for ch in self._order
                ):
                    continue                # a resume re-activated a peer
                if any(p.suspended for p in self._peers.values()):
                    time.sleep(_POLL_S)     # wait out the resume window
                    continue
                break
            rnd = self._rnd = rnd + 1

            # barrier phase 1: live peers owe ROUND_SKETCHES, finished
            # peers owe VERIFY — collect both in one round-robin sweep
            expect = {
                p.channel: (
                    wf.MSG_ROUND_SKETCHES if self._peer_live(p, rnd)
                    else wf.MSG_VERIFY
                )
                for p in active
            }
            with tracer.span("hub.collect_sketches", cat="wire", round=rnd,
                             peers=len(expect)):
                frames = self._collect(expect)
            for ch, payload in list(frames.items()):
                if expect[ch] == wf.MSG_VERIFY:
                    self._finish_peer(self._peers[ch], payload)
                    del frames[ch]

            # shared plan over every surviving live session (evictions
            # above already marked their sessions failed), then the fused
            # single-side encode: 2 kernel launches per cohort, all peers
            plans = self._batch.plan_round(rnd)
            # launch counters are bumped at the dispatch sites inside the
            # helpers, so the fusion stats measure dispatches — one encode
            # and one decode per cohort regardless of peer count — rather
            # than echoing the planner's own bookkeeping
            with tracer.span("hub.encode", cat="device", round=rnd,
                             cohorts=len(plans)):
                per = encode_round_rows(plans, self.side, self._interpret,
                                        launches=st)
            if plans:
                st["rounds"] = rnd
            st["cohort_rounds"] += len(plans)
            st["h2d_round_bytes"] += sum(p.h2d_bytes for p in plans)

            round_ctx = self._apply_sketches(rnd, frames, plans, per)

            # barrier phase 2: the per-peer checksum-outcome frames
            with tracer.span("hub.collect_outcomes", cat="wire", round=rnd,
                             peers=len(round_ctx)):
                outcomes = self._collect({
                    ch: wf.MSG_ROUND_OUTCOME for ch in round_ctx
                })
            for ch, payload in outcomes.items():
                with tracer.span("peer.round.outcome", round=rnd, channel=ch,
                                 peer=self._peers[ch].label):
                    self._apply_outcome(self._peers[ch], rnd, payload,
                                        *round_ctx[ch])
            tracer.instant("hub.barrier", round=rnd, epoch=self._epoch,
                           peers=len(active))

            if self._degrade:
                # graceful degradation (DESIGN.md §13): any session one
                # round from exhausting its budget with work left re-plans
                # at a doubled d̂; both sides run this at the same barrier
                st["sessions_degraded"] += len(
                    degrade_exhausted(self._batch, rnd)
                )

            if self.on_barrier is not None:
                hook_fired_at = rnd
                self.on_barrier(rnd)
            self._admit(rnd)

        st["store_uploads"] = self._batch.store_builds
        # per-serve continuous-sync ledger: store uploads, rebuilds, and
        # delta-patch bytes THIS epoch paid for (DESIGN.md §11) — a
        # zero-rebuild epoch shows store_builds == 0, zero store bytes,
        # and only O(churn) delta bytes (store_uploads stays cumulative:
        # the one-per-cohort fusion contract the acceptance test asserts)
        delta = {
            k: v - prior[k] for k, v in self._batch.counters().items()
        }
        st["h2d_store_bytes"] = delta["store_build_bytes"]
        st["store_builds"] = delta["store_builds"]
        st["store_compactions"] = delta["store_compactions"]
        st["h2d_delta_bytes"] = delta["store_delta_bytes"]
        st["h2d_bytes"] = (
            st["h2d_store_bytes"] + st["h2d_round_bytes"]
            + st["h2d_delta_bytes"]
        )
        # jit cache-miss ledger (DESIGN.md §12): compilations THIS serve
        # triggered across every kernel entry point — a warm hub epoch
        # re-uses the pow2-bucketed signatures and reports 0
        st["retraces"] = retrace_count() - retrace_mark
        # the freeze point is the publish point: the legacy `stats` view
        # derives back from these registry rows (DESIGN.md §14)
        self.recorder.publish("hub", st)
        self.recorder.publish("store", self._batch.counters())
        self.recorder.set("kernels.retraces_total", retrace_count())
        self.recorder.set("kernels.retraces_by_fn", retrace_counts())
        if tracer.enabled:
            for ch in self._order:
                p = self._peers[ch]
                tracer.instant(
                    "peer.result", channel=ch, peer=p.label,
                    ok=p.error is None, kind=self._peer_kind(p),
                    rounds=p.rounds_done, resumes=p.resumes,
                    protocol_bytes=p.tally["protocol"],
                    resume_bytes=p.tally["resume"],
                )
        return {
            ch: PeerOutcome(
                channel=ch,
                ok=self._peers[ch].error is None,
                verified=self._peers[ch].verified,
                error=self._peers[ch].error,
                sessions=self._peers[ch].sessions,
                wire_stats=self._peers[ch].wire_stats(),
                error_kind=self._peer_kind(self._peers[ch]),
                tree_depth=self._peers[ch].tree_depth,
                tree_leaves=self._peers[ch].tree_leaves,
            )
            for ch in self._order
        }

    def _peer_kind(self, peer: _Peer) -> str | None:
        """The ``PeerOutcome.error_kind`` taxonomy value for one peer:
        failures classify by root cause; successful peers report which
        recovery path they took (``resumed`` wins over ``degraded`` when
        both fired), or None for a clean run."""
        if peer.error is not None:
            return classify_error(peer.error)
        if peer.resumes:
            return "resumed"
        if any(s.escalations for s in peer.sessions):
            return "degraded"
        return None

    @property
    def stats(self) -> dict:
        """Fusion ledger of the last ``serve``: global rounds, cohort
        rounds, kernel/decode launches (2 + 1 per cohort-round, shared
        across all peers), and the store-upload accounting.

        A derived snapshot of the ``hub.*`` metrics in the recorder: the
        working ledger is re-published on read (mid-serve mutations like
        evictions land immediately) and the dict rebuilds from the
        registry rows — same keys and values as the pre-obs ad-hoc dict
        (DESIGN.md §14)."""
        st = dict(self._stats)
        self.recorder.publish("hub", st)
        view = self.recorder.view("hub")
        return {k: view[k] for k in st}

    # -- round internals ---------------------------------------------------

    def _apply_sketches(self, rnd: int, frames: dict[int, bytes], plans, per):
        """Decode every peer's sketch frame against its schema, run ONE
        batched BCH decode per cohort across all peers' units, and send
        each surviving peer its reply frame.  Returns the per-peer outcome
        context for barrier phase 2."""
        # per peer: her live sessions in local-sid order + decoded blocks
        sk_a_of: dict[int, np.ndarray] = {}     # global sid -> (U, t)
        peer_live: dict[int, list[int]] = {}    # channel -> global sids
        for ch, payload in frames.items():
            peer = self._peers[ch]
            live_g = [s.sid for s in peer.sessions if s.sid in per]
            try:
                got_rnd, blocks = wf.decode_round_sketches(
                    payload, round_schema(per, live_g)
                )
                local = rnd - peer.rnd0
                if got_rnd != local:
                    raise WireError(
                        f"sketch frame for round {got_rnd}, expected {local}"
                    )
            except WireError as e:
                self._evict(peer, e)
                continue
            peer.tally["protocol"] += framed_len(len(payload))
            peer_live[ch] = live_g
            sk_a_of.update(zip(live_g, blocks))

        # one decode launch per cohort, all peers' units stacked; sessions
        # of peers evicted after planning keep zero rows and are skipped
        with self.tracer.span("hub.decode", cat="device", round=rnd,
                              cohorts=len(plans)):
            results, ctx = decode_side_b_round(plans, per, sk_a_of,
                                               launches=self._stats)

        round_ctx: dict[int, tuple] = {}
        for ch, live_g in peer_live.items():
            peer = self._peers[ch]
            local = rnd - peer.rnd0
            with self.tracer.span("peer.round.reply", round=rnd, channel=ch,
                                  peer=peer.label, sessions=len(live_g)):
                reply = wf.encode_round_reply(
                    local, [results[g] for g in live_g],
                    round_schema(per, live_g),
                )
                try:
                    peer.stream.send(reply)
                except TransportError as e:
                    self._fail(peer, e, resumable=True)
                    continue
            peer.tally["protocol"] += len(reply)
            # the reply is out: the peer may now complete the round on her
            # side, so retain the outcome context for an idempotent replay
            # if she crashes before her outcome frame lands (DESIGN.md §13)
            peer.inflight_ctx = (live_g, ctx)
            round_ctx[ch] = (live_g, ctx)
        if round_ctx:
            self._rateless_phase(rnd, plans, per, sk_a_of, round_ctx)
        return round_ctx

    def _rateless_phase(self, rnd, plans, per, sk_a_of, round_ctx) -> None:
        """Serve the rateless recovery ladder (DESIGN.md §16) between the
        reply send and the outcome barrier.

        Every peer with failing rateless units owes one ``MSG_PARITY``
        frame per ladder level, collected through the shared poller; the
        hub answers each level with ONE incremental encode dispatch and
        ONE extended decode per cohort, shared across all peers, and
        merges recovered verdicts into the retained round contexts in
        place — the outcome frames (and any resume replay from
        ``inflight_ctx``, which aliases the same ``ctx`` tuples) see the
        post-ladder verdicts.  Peers that fail mid-ladder drop out of
        ``round_ctx`` so the outcome barrier never polls them; a
        suspended peer's re-run starts the round (and its ladder) from
        scratch, so partial merges never leak into session state."""
        fail: dict[int, dict[int, list[int]]] = {}      # ch -> sid -> slots
        for ch, (live_g, ctx) in round_ctx.items():
            bad = {}
            for sid in live_g:
                sess, active, ok, _ = ctx[sid]
                if not sess.plan.cfg.rateless:
                    continue
                slots = [s for s in range(len(active)) if not ok[s]]
                if slots:
                    bad[sid] = slots
            if bad:
                fail[ch] = bad
        if not fail:
            return
        st = self._stats
        acc: dict[int, dict[int, np.ndarray]] = {}      # sid -> slot -> syn
        for level in range(1, MAX_PARITY_EXTENSIONS + 1):
            if not fail:
                return
            failing = {sid for bad in fail.values() for sid in bad}
            part_plans = [
                plan for plan in plans
                if any(sess.sid in failing for sess, *_ in plan.members)
            ]
            with self.tracer.span("hub.parity_encode", cat="device",
                                  round=rnd, level=level,
                                  cohorts=len(part_plans)):
                inc_of = encode_round_rows_ext(
                    part_plans, self.side, level, self._interpret,
                    launches=st,
                )
            # mirror of each peer's own participation check: failing
            # sessions whose cohort t still grows at this level
            need: dict[int, list[int]] = {}
            for ch, bad in fail.items():
                parts = [
                    sid for sid in round_ctx[ch][0]
                    if sid in bad and sid in inc_of
                ]
                if parts:
                    need[ch] = parts
            if not need:
                return
            with self.tracer.span("hub.collect_parity", cat="wire",
                                  round=rnd, level=level, peers=len(need)):
                frames = self._collect({
                    ch: wf.MSG_PARITY for ch in need
                })
            for ch in list(need):
                if ch not in frames:    # evicted/suspended at the barrier
                    del need[ch]
                    fail.pop(ch, None)
                    round_ctx.pop(ch, None)
            # fold each peer's incremental columns into its failing
            # units' accumulated diff syndromes (prefix cached at decode)
            for ch, payload in frames.items():
                peer = self._peers[ch]
                bad = fail[ch]
                parts = need[ch]
                schema = [
                    (len(bad[sid]), inc_of[sid][2] - inc_of[sid][1],
                     per[sid].plan.store.m)
                    for sid in parts
                ]
                try:
                    got_rnd, got_level, blocks = wf.decode_parity(
                        payload, schema
                    )
                    local = rnd - peer.rnd0
                    if got_rnd != local:
                        raise WireError(
                            f"parity frame for round {got_rnd}, "
                            f"expected {local}"
                        )
                    if got_level != level:
                        raise WireError(
                            f"parity frame at level {got_level}, "
                            f"expected {level}"
                        )
                except WireError as e:
                    self._evict(peer, e)
                    del need[ch]
                    del fail[ch]
                    round_ctx.pop(ch, None)
                    continue
                peer.tally["protocol"] += framed_len(len(payload))
                for sid, inc_a in zip(parts, blocks):
                    inc_b = inc_of[sid][0]
                    prefix_a = sk_a_of[sid]
                    sk_b = per[sid].sk
                    slot_acc = acc.setdefault(sid, {})
                    for i, slot in enumerate(bad[sid]):
                        prev = slot_acc.get(slot)
                        if prev is None:
                            prev = np.asarray(
                                prefix_a[slot], dtype=np.int64
                            ) ^ np.asarray(sk_b[slot], dtype=np.int64)
                        d = np.asarray(
                            inc_a[i], dtype=np.int64
                        ) ^ np.asarray(inc_b[slot], dtype=np.int64)
                        slot_acc[slot] = np.concatenate([prev, d])
            if not need:
                continue
            # reply schemas before the merge loop mutates ``fail``: each
            # ext reply covers every unit failing at this level, at t1
            reply_schema = {
                ch: [
                    (len(fail[ch][sid]), inc_of[sid][2],
                     per[sid].plan.store.m)
                    for sid in parts
                ]
                for ch, parts in need.items()
            }
            ch_of = {sid: ch for ch, parts in need.items() for sid in parts}
            entries: dict[int, tuple] = {}
            with self.tracer.span("hub.parity_decode", cat="device",
                                  round=rnd, level=level):
                for plan in part_plans:
                    n, t = plan.store.n, plan.store.t
                    t1 = parity_extension_t(t, level, n)
                    if t1 <= parity_extension_t(t, level - 1, n):
                        continue
                    u_pad = plan.arrays["row_map"].shape[0]
                    buf = np.zeros((u_pad, t1), dtype=np.int64)
                    hit = False
                    for sess, base, active, _ in plan.members:
                        ch = ch_of.get(sess.sid)
                        if ch is None:
                            continue
                        for slot in fail[ch][sess.sid]:
                            buf[base + slot] = acc[sess.sid][slot]
                            hit = True
                    if not hit:
                        continue
                    ok_p, pos_p, cnt_p = (
                        np.asarray(x) for x in jax.device_get(
                            bch_decode_batched(
                                jnp.asarray(buf, dtype=jnp.int32), n=n, t=t1
                            )
                        )
                    )
                    st["decode_launches"] = st.get("decode_launches", 0) + 1
                    for sess, base, active, _ in plan.members:
                        sid = sess.sid
                        ch = ch_of.get(sid)
                        if ch is None:
                            continue
                        row = per[sid]
                        ok_m = round_ctx[ch][1][sid][2]
                        ok_e, units, still = [], [], []
                        for slot in fail[ch][sid]:
                            if ok_p[base + slot]:
                                k = int(cnt_p[base + slot])
                                p = pos_p[base + slot, :k].astype(np.int64)
                                units.append(
                                    ReplyUnit(
                                        positions=p,
                                        xors=row.xors[slot, p],
                                        csum=int(row.csum[slot]),
                                    )
                                )
                                ok_e.append(True)
                                ok_m[slot] = True   # in place: outcome +
                                # resume replay see the ladder verdict
                            else:
                                units.append(None)
                                ok_e.append(False)
                                still.append(slot)
                        entries[sid] = (ok_e, units)
                        if still:
                            fail[ch][sid] = still
                        else:
                            del fail[ch][sid]
                        st["parity_extensions"] = (
                            st.get("parity_extensions", 0) + 1
                        )
                        self.tracer.instant(
                            "hub.parity_extension", channel=ch, sid=sid,
                            round=rnd, level=level,
                            units=len(ok_e), t=t1,
                        )
            for ch, parts in need.items():
                peer = self._peers[ch]
                reply = wf.encode_round_reply(
                    rnd - peer.rnd0,
                    [entries[sid] for sid in parts],
                    reply_schema[ch],
                )
                try:
                    peer.stream.send(reply)
                except TransportError as e:
                    self._fail(peer, e, resumable=True)
                    fail.pop(ch, None)
                    round_ctx.pop(ch, None)
                    continue
                peer.tally["protocol"] += len(reply)
                if not fail.get(ch):
                    fail.pop(ch, None)

    def _apply_outcome(self, peer: _Peer, rnd: int, payload: bytes,
                       live_g: list[int], ctx: dict[int, tuple],
                       *, replay: bool = False) -> None:
        """Mirror one peer's unit-queue evolution from her outcome frame:
        our decode failures drive the same deterministic 3-way split, her
        flags settle the checksums we cannot compute (we never see A).
        Applying the frame commits the peer's round barrier: the transcript
        digest folds the exact framed bytes she folded, the recovery record
        advances, and the tally marks snapshot — the state ``resume_peer``
        validates against.  ``replay=True`` routes the frame's bytes to the
        resume tally (transport overhead, never Formula-(1) bits)."""
        try:
            got_rnd, done_lists = wf.decode_round_outcome(
                payload, [len(ctx[g][1]) for g in live_g]
            )
            local = rnd - peer.rnd0
            if got_rnd != local:
                raise WireError(
                    f"outcome frame for round {got_rnd}, expected {local}"
                )
        except WireError as e:
            self._evict(peer, e)
            return
        if replay:
            peer.tally["resume"] += framed_len(len(payload))
            self._stats["resume_replay_bytes"] = (
                self._stats.get("resume_replay_bytes", 0)
                + framed_len(len(payload))
            )
        else:
            peer.tally["protocol"] += framed_len(len(payload))
        for g, done in zip(live_g, done_lists):
            sess, active, ok, _ = ctx[g]
            sloc = rnd - sess.rnd0
            for slot, u in enumerate(active):
                if not ok[slot]:
                    queue_split(sess.state, u, sloc, sess.plan.cfg.seed)
                elif done[slot]:
                    u.done = True
            sess.state.rounds = sloc
        # barrier committed: fold the same bytes the peer folded (her frame
        # numbering is our local round) and advance the recovery record
        peer.digest_prev = peer.digest
        peer.digest = wf.fold_transcript(
            peer.digest, local, wf.frame(wf.MSG_ROUND_OUTCOME, payload)
        )
        peer.rounds_done = local
        peer.inflight_ctx = None
        peer.marks = {k: peer.tally[k] for k in peer.marks}


def _drive_hub(
    hub: HubEndpoint,
    peer_calls: dict[int, object],
    join_timeout: float,
):
    """Run one hub ``serve`` against one callable per peer channel."""
    results: dict[int, dict[int, ReconcileResult]] = {}
    errors: dict[int, BaseException] = {}

    def _drive(ch: int, call):
        try:
            results[ch] = call()
        except BaseException as e:  # noqa: BLE001 - reported per peer
            errors[ch] = e

    threads = [
        threading.Thread(target=_drive, args=(ch, call),
                         name=f"peer-{ch}", daemon=True)
        for ch, call in peer_calls.items()
    ]
    for th in threads:
        th.start()
    outcomes = hub.serve()
    for th in threads:
        th.join(timeout=join_timeout)
    return outcomes, results, errors


def run_hub(
    hub: HubEndpoint,
    alices: dict[int, AliceEndpoint],
    *,
    join_timeout: float = 120.0,
):
    """Drive a hub and its connected peers concurrently: each Alice on a
    worker thread, the hub on the caller's thread.

    Returns ``(outcomes, results, errors)``: the hub's per-channel
    ``PeerOutcome``s, per-channel Alice results (``sid -> ReconcileResult``)
    for peers whose ``run`` completed, and per-channel exceptions for peers
    whose ``run`` raised (evicted stragglers see their transport closed, so
    they fail fast with ``TransportError`` instead of hanging).
    """
    return _drive_hub(
        hub, {ch: ep.run for ch, ep in alices.items()}, join_timeout
    )


def run_hub_epoch(
    hub: HubEndpoint,
    alices: dict[int, AliceEndpoint],
    *,
    join_timeout: float = 120.0,
):
    """Drive one staged continuous-sync epoch (DESIGN.md §11): the hub and
    every surviving peer must have called ``advance_epoch``; each Alice
    runs ``run_epoch`` on a worker thread against one hub ``serve``.  Same
    return shape and per-peer error semantics as ``run_hub``.
    """
    return _drive_hub(
        hub, {ch: ep.run_epoch for ch, ep in alices.items()}, join_timeout
    )
