"""Alice/Bob PBS endpoints: the wire-separated halves of the protocol.

Each endpoint owns exactly one side's data and device pipeline:

* ``AliceEndpoint`` holds the A sets, runs phase 0 (ToW sketch out, d_hat
  numerator back), encodes her per-unit BCH sketches each round through the
  single-side cohort executor (``recon.engine.encode_side`` over her
  device-resident ``SessionBatch(sides=("a",))`` stores), applies the
  shared ``core.pbs.apply_round_outcomes`` to Bob's reply frames, and ships
  the checksum verdicts back as outcome frames.
* ``BobEndpoint`` mirrors the session/unit state machine from the frames
  alone: his own decode failures drive ``queue_split`` exactly like
  Alice's, and her outcome frames supply the checksum-settled flags he
  cannot compute (he never sees A).  His side batches the same way —
  encode his sketches per cohort, XOR with the frame-decoded sketches,
  ``bch_decode_batched`` for every unit of a cohort in one call.

Byte ledgers are *measured*: every ``bytes_per_round`` entry an endpoint
reports is derived from the frames that crossed the transport (via the
``repro.wire`` ledger-bit helpers on decoded content), then asserted equal
to the Formula-(1) accounting the in-process oracle computes — so
``ReconcileResult.bytes_sent`` from this path is a wire measurement that
happens to equal ``core.pbs.reconcile``'s ledger exactly.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hashing import derive_seed
from repro.core.pbs import (
    MAX_PARITY_EXTENSIONS,
    PBSConfig,
    ReconcileResult,
    apply_round_outcomes,
    checksum,
    effective_set,
    finalize_result,
    new_session_state,
    parity_extension_t,
    plan_from_d_known,
    plan_from_estimate,
    queue_split,
)
from repro.core.tow import (
    ESTIMATE_LIMIT_FRAC,
    check_estimate,
    estimate_numerator,
    planned_d,
    tow_sketches,
)
from repro.kernels.ops import bch_decode_batched
from repro.obs import NULL_TRACER, Recorder
from repro.recon.engine import encode_side, encode_side_ext
from repro.recon.session import (
    CohortRoundPlan,
    ReconSession,
    SessionBatch,
    advance_session,
    apply_churn,
    degrade_exhausted,
)
from repro.tree.partition import (
    SPAN,
    TreeConfig,
    TreeLeaf,
    leaf_slices,
    level_digests,
    level_verdicts,
    split_ranges,
)
from repro.wire import frames as wf
from repro.wire.frames import ReplyUnit, WireError
from repro.wire.varint import framed_len

from .transport import FrameStream, Transport

_EMPTY = np.zeros(0, dtype=np.uint32)

_ROUND_ARRAY_KEYS = (
    "row_map", "unit_valid", "seeds", "removed", "removed_cnt",
    "added", "added_cnt", "fseeds", "fbins", "fcnt",
)


@dataclass
class _SessionRows:
    """One live session's slice of its cohort's device outputs this round."""

    sess: ReconSession
    active: list
    bin_seed: int
    sk: np.ndarray        # (U, t) syndromes
    xors: np.ndarray      # (U, n) uint32 bin XOR folds
    csum: np.ndarray      # (U,) uint32 unit checksums
    plan: CohortRoundPlan


def encode_round_rows(
    plans: list[CohortRoundPlan],
    side: str,
    interpret: bool | None,
    launches: dict | None = None,
) -> dict[int, _SessionRows]:
    """Dispatch every cohort's single-side executor, then collect per-session
    row slices (async dispatch overlaps cohorts).  Shared by the pair
    endpoints and the multi-peer hub — the hub's ``plans`` span all peers'
    sessions, so the two launches per cohort are fused across peers.

    ``launches`` (if given) is bumped at the dispatch site — one
    ``encode_side`` call is one bin-kernel launch plus one sketch matmul —
    so the hub's fusion stats measure dispatches, not planner bookkeeping.
    """
    inflight = []
    for plan in plans:
        store = plan.store
        ss = store.sides[side]
        out = encode_side(
            ss.flat, ss.start, ss.cnt,
            *(jnp.asarray(plan.arrays[k]) for k in _ROUND_ARRAY_KEYS),
            n=store.n,
            t=store.t,
            width=plan.width_a if side == "a" else plan.width_b,
            interpret=interpret,
        )
        if launches is not None:
            launches["kernel_launches"] = launches.get("kernel_launches", 0) + 2
        inflight.append((plan, out))
    per: dict[int, _SessionRows] = {}
    for plan, out in inflight:
        sk, xors, csum = (np.asarray(x) for x in jax.device_get(out))
        for sess, base, active, bin_seed in plan.members:
            rows = slice(base, base + len(active))
            per[sess.sid] = _SessionRows(
                sess, active, bin_seed, sk[rows], xors[rows], csum[rows], plan
            )
    return per


def encode_round_rows_ext(
    plans: list[CohortRoundPlan],
    side: str,
    level: int,
    interpret: bool | None,
    launches: dict | None = None,
) -> dict[int, tuple]:
    """Dispatch every cohort's *incremental* single-side executor for one
    rateless ladder level (DESIGN.md §16) and collect per-session slices.

    Per cohort the syndrome matmul covers only columns
    [t_{level-1}·m, t_level·m) of the (n, t_level) code — the
    ``MSG_PARITY`` payload.  Cohorts whose t-ladder cannot grow at this
    level (the (n-1)//2 code cap) are skipped.  Shared by the pair
    endpoints and the multi-peer hub, which passes plans spanning all
    peers so the two launches per cohort stay fused across peers.

    Returns sid -> (inc (U, t1-t0) int array, t0, t1).
    """
    inflight = []
    for plan in plans:
        store = plan.store
        n, t = store.n, store.t
        t0 = parity_extension_t(t, level - 1, n)
        t1 = parity_extension_t(t, level, n)
        if t1 <= t0:
            continue
        ss = store.sides[side]
        out = encode_side_ext(
            ss.flat, ss.start, ss.cnt,
            *(jnp.asarray(plan.arrays[k]) for k in _ROUND_ARRAY_KEYS),
            n=n, t0=t0, t1=t1,
            width=plan.width_a if side == "a" else plan.width_b,
            interpret=interpret,
        )
        if launches is not None:
            launches["kernel_launches"] = launches.get("kernel_launches", 0) + 2
        inflight.append((plan, t0, t1, out))
    per: dict[int, tuple] = {}
    for plan, t0, t1, out in inflight:
        inc = np.asarray(jax.device_get(out))
        for sess, base, active, _ in plan.members:
            per[sess.sid] = (inc[base : base + len(active)], t0, t1)
    return per


def round_schema(per: dict[int, _SessionRows], live: list[int]):
    """The frame schema for the given sids, in the given order: both wire
    sides derive it from the same deterministic round state, so frames ship
    no redundant structure (DESIGN.md §9)."""
    return [
        (len(per[sid].active), per[sid].plan.store.t, per[sid].plan.store.m)
        for sid in live
    ]


def serve_phase0(payload: bytes, set_b, cfg: PBSConfig,
                 limit_frac: float | None = ESTIMATE_LIMIT_FRAC):
    """Answer one peer's phase-0 ToW sketch frame (the serving side).

    Returns (d_hat reply frame, the pinned ProtocolPlan, estimator ledger
    bytes covering both framed messages).  Raises ``EstimateOutOfRange``
    when the planned d̂ leaves the PBS operating regime for the pair's
    size (``limit_frac=None`` disables — the legacy burn-the-budget
    behavior); the tree front end (§15) is the route for such pairs.
    Shared by ``BobEndpoint`` and the multi-peer hub so the two serving
    paths cannot drift.
    """
    set_size_a, sk_a = wf.decode_tow_sketch(payload)
    if len(sk_a) != cfg.ell:
        raise WireError(
            f"peer sent {len(sk_a)} ToW sketches, cfg.ell={cfg.ell}"
        )
    sk_b = tow_sketches(set_b, derive_seed(cfg.seed, 0x70), cfg.ell)
    num = estimate_numerator(sk_a, sk_b)
    reply = wf.encode_dhat(num)
    est_bytes = _framed_len(payload) + len(reply)
    plan = plan_from_estimate(cfg, num, set_size_a)
    check_estimate(
        planned_d(plan.d_est, cfg.gamma), set_size_a + len(set_b), limit_frac
    )
    return reply, plan, est_bytes


def tree_walk_state(elems, cfg: PBSConfig, tcfg: TreeConfig) -> dict:
    """Fresh serving-side tree-walk state (§15): the staged set plus the
    root frontier, the level clock, and the leaf accumulator."""
    return {
        "elems": elems, "cfg": cfg, "tcfg": tcfg,
        "frontier": [(0, SPAN)], "level": 0, "leaves": [], "bytes": 0,
    }


def serve_tree_frame(payload: bytes, walk: dict, stream, tally: dict,
                     tracer, interpret: bool | None) -> bool:
    """Serve one inbound ``MSG_TREE`` digest frame (the serving side's half
    of one tree-walk level, §15); returns True when the walk completed.

    Digest our own frontier — one batched ``tree_digest`` launch — compute
    the verdicts (the serving side holds both digest sets), ship them back,
    and advance the frontier by the shared deterministic split rule.
    Accumulates ``TREE_LEAF`` ranges into ``walk["leaves"]`` and the framed
    exchange bytes into both ``tally["tree"]`` and ``walk["bytes"]``.
    Shared by ``BobEndpoint`` and the multi-peer hub so the two serving
    paths cannot drift.
    """
    elems, tcfg, frontier = walk["elems"], walk["tcfg"], walk["frontier"]
    level, ell, cnt_a, cs_a, sk_a = wf.decode_tree_digest(payload)
    if level != walk["level"]:
        raise WireError(
            f"tree digest for level {level} at level {walk['level']}"
        )
    if ell != tcfg.ell:
        raise WireError(f"tree digest ell {ell}, configured {tcfg.ell}")
    if len(cnt_a) != len(frontier):
        raise WireError(
            f"tree digest covers {len(cnt_a)} ranges, "
            f"frontier has {len(frontier)}"
        )
    tally["tree"] += _framed_len(payload)
    walk["bytes"] += _framed_len(payload)
    with tracer.span("tree.level.dispatch", cat="device",
                     level=level, ranges=len(frontier)):
        cnt_b, cs_b, sk_b = level_digests(
            elems, frontier, tcfg, interpret=interpret
        )
    with tracer.span("tree.level.collect", cat="wire",
                     level=level, ranges=len(frontier)):
        verdicts, leaf_ds = level_verdicts(
            level, cnt_a, cs_a, sk_a, cnt_b, cs_b, sk_b, tcfg
        )
        reply = wf.encode_tree_verdict(level, verdicts, leaf_ds)
        stream.send(reply)
        tally["tree"] += len(reply)
        walk["bytes"] += len(reply)
        li = 0
        for (lo, hi), v in zip(frontier, verdicts):
            if v == wf.TREE_LEAF:
                walk["leaves"].append(
                    TreeLeaf(lo=lo, hi=hi, d_plan=int(leaf_ds[li]))
                )
                li += 1
        walk["frontier"] = split_ranges(frontier, verdicts)
        walk["level"] = level + 1
    return not walk["frontier"]


def serve_epoch_frame(payload: bytes, expected_epoch: int, pending: dict,
                      plans: dict, cfg_of, stream, tally: dict,
                      limit_frac: float | None = ESTIMATE_LIMIT_FRAC) -> bool:
    """Serve one inbound ``MSG_EPOCH`` frame (the serving side's half of
    the epoch handshake, DESIGN.md §11); returns True when the peer owes
    no more epoch frames.

    ``pending`` maps sid -> (staged set, d convention) for the staged
    epoch; estimator sids (convention None) are served in sorted order —
    the same positional contract as ``submit`` — each wrapped ToW sketch
    answered with a wrapped d̂ reply through the shared ``serve_phase0``,
    recording the plan in ``plans``.  A bare epoch-open is only legal
    when nothing re-estimates, and is answered bare.  Ledger mirrors
    ``MSG_MUX``: inner phase-0 bits to the estimator tally, envelope
    bytes to the epoch tally.  Shared by ``BobEndpoint`` and the hub so
    the two serving paths cannot drift.
    """
    e, ity, ipayload = wf.decode_epoch(payload)
    if e != expected_epoch:
        raise WireError(f"epoch frame for epoch {e}, expected {expected_epoch}")
    est = [
        sid for sid in sorted(pending)
        if pending[sid][1] is None and sid not in plans
    ]
    if ity is None:
        if est:
            raise WireError("bare epoch-open with estimator sessions pending")
        reply = wf.encode_epoch(e)
        stream.send(reply)
        tally["epoch"] += _framed_len(payload) + len(reply)
        return True
    if ity != wf.MSG_TOW_SKETCH:
        raise WireError(f"unexpected epoch inner frame type 0x{ity:02x}")
    if not est:
        raise WireError("epoch ToW frame with no estimator session pending")
    sid = est[0]
    elems, _ = pending[sid]
    inner_reply, plan, est_bytes = serve_phase0(
        ipayload, elems, cfg_of(sid), limit_frac
    )
    reply = wf.encode_epoch(e, inner_reply)
    stream.send(reply)
    tally["estimator"] += est_bytes
    tally["epoch"] += (
        _framed_len(payload) - framed_len(len(ipayload))
        + len(reply) - len(inner_reply)
    )
    plans[sid] = plan
    return len(est) == 1


def decode_side_b_round(
    plans,
    per: dict[int, _SessionRows],
    sk_a_of: dict,
    launches: dict | None = None,
):
    """The serving side's round completion: place each session's
    frame-decoded sketches at its cohort rows, XOR with the resident side,
    run ONE ``bch_decode_batched`` launch per cohort, and build every
    session's reply entry.

    ``sk_a_of`` maps sid -> (U, t) frame sketches; sessions absent from it
    (an evicted hub peer) keep zero rows — padding decodes trivially-ok and
    they are skipped in the result.  Returns (results: sid -> (ok, units),
    ctx: sid -> (sess, active, ok, bin_seed)) — ``ctx`` is what the
    outcome-frame mirror needs.  Shared by ``BobEndpoint`` and the hub; in
    the hub's case ``plans`` span every peer, so the decode launch is fused
    across peers.
    """
    inflight = []
    for plan in plans:
        u_pad = plan.arrays["row_map"].shape[0]
        sk_a = np.zeros((u_pad, plan.store.t), dtype=np.int32)
        sk_b = np.zeros((u_pad, plan.store.t), dtype=np.int32)
        for sess, base, active, _ in plan.members:
            if sess.sid not in sk_a_of:
                continue
            rows = slice(base, base + len(active))
            sk_a[rows] = sk_a_of[sess.sid]
            sk_b[rows] = per[sess.sid].sk
        out = bch_decode_batched(
            jnp.asarray(sk_a ^ sk_b, dtype=jnp.int32),
            n=plan.store.n, t=plan.store.t,
        )
        if launches is not None:
            launches["decode_launches"] = launches.get("decode_launches", 0) + 1
        inflight.append((plan, out))
    results: dict[int, tuple] = {}
    ctx: dict[int, tuple] = {}
    for plan, out in inflight:
        ok_pad, pos_pad, cnt_pad = (np.asarray(x) for x in jax.device_get(out))
        # writable: the rateless ladder merges extension verdicts into the
        # per-session ok views in place (DESIGN.md §16)
        ok_pad = np.array(ok_pad)
        for sess, base, active, bin_seed in plan.members:
            if sess.sid not in sk_a_of:
                continue
            rows = slice(base, base + len(active))
            row = per[sess.sid]
            ok = ok_pad[rows]
            pos, cnt = pos_pad[rows], cnt_pad[rows]
            units: list[ReplyUnit | None] = []
            for slot in range(len(active)):
                if not ok[slot]:
                    units.append(None)
                    continue
                k = int(cnt[slot])
                p = pos[slot, :k].astype(np.int64)
                units.append(
                    ReplyUnit(
                        positions=p,
                        xors=row.xors[slot, p],
                        csum=int(row.csum[slot]),
                    )
                )
            results[sess.sid] = (ok, units)
            ctx[sess.sid] = (sess, active, ok, bin_seed)
    return results, ctx


def verify_ack_entries(payload: bytes, sessions):
    """Decode a VERIFY frame and compute the serving side's verdicts:
    the peer claims success AND c(A △ D̂) equals our c(B).  Returns
    (ack frame, flags).  Shared by ``BobEndpoint`` and the hub."""
    entries = wf.decode_verify(payload, len(sessions))
    flags = [
        bool(success) and csum_eff == checksum(sess.state.b)
        for sess, (success, csum_eff) in zip(sessions, entries)
    ]
    return wf.encode_verify_ack(flags), flags


def stream_wire_stats(
    stream: FrameStream, tally: dict, carry: dict | None = None
) -> dict:
    """Measured wire traffic of one stream: exact framed bytes by category
    plus the transport totals (which additionally see ARQ and mux-envelope
    overhead, if any).  ``retransmits``/``rto_ms`` surface the ARQ layer's
    adaptive-retry state when the transport has one (DESIGN.md §13);
    ``resume_frame_bytes`` is the resumption tally — handshake, replayed
    frames, and any aborted partial round, all transport overhead, never
    Formula-(1) bits.  ``carry`` adds the transport byte totals of streams
    torn down by earlier resumptions so the counters stay cumulative."""
    t = stream.transport
    carry = carry or {}
    return {
        "frames_out": stream.frames_out,
        "frames_in": stream.frames_in,
        "frame_bytes_out": stream.bytes_out,
        "frame_bytes_in": stream.bytes_in,
        "transport_bytes_out": t.bytes_out + carry.get("transport_bytes_out", 0),
        "transport_bytes_in": t.bytes_in + carry.get("transport_bytes_in", 0),
        "mux_bytes_out": stream.mux_bytes_out,
        "mux_bytes_in": stream.mux_bytes_in,
        "estimator_frame_bytes": tally["estimator"],
        "protocol_frame_bytes": tally["protocol"],
        "verify_frame_bytes": tally["verify"],
        "epoch_envelope_bytes": tally.get("epoch", 0),
        "resume_frame_bytes": tally.get("resume", 0),
        "tree_frame_bytes": tally.get("tree", 0),
        "retransmits": getattr(t, "retransmits", 0) + carry.get("retransmits", 0),
        "rto_ms": getattr(t, "rto_ms", None),
    }


class _Endpoint:
    """Shared plumbing: submissions, cohort batch, side encode, tallies."""

    side: str

    def __init__(
        self,
        transport: Transport,
        *,
        interpret: bool | None = None,
        channel: int | None = None,
        continuous: bool = False,
        degrade: bool = False,
        estimate_limit: float | None = ESTIMATE_LIMIT_FRAC,
        recorder: Recorder | None = None,
        tracer=None,
    ):
        self._stream = FrameStream(transport, channel=channel)
        self._interpret = interpret
        # telemetry (DESIGN.md §14): wire_stats derives from the recorder's
        # wire.* rows; spans/instants go through the tracer (NULL_TRACER =
        # disabled, free)
        self.recorder = recorder if recorder is not None else Recorder()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._continuous = continuous
        self._degrade = degrade
        # phase-0 operating-regime guard (§15): planned d̂ beyond this
        # fraction of |A| + |B| raises EstimateOutOfRange; None disables
        self._estimate_limit = estimate_limit
        self._sessions: list[ReconSession | None] = []
        self._est_queue: list[int] = []     # sids awaiting phase 0, in order
        self._batch: SessionBatch | None = None
        self._tally = {
            "estimator": 0, "protocol": 0, "verify": 0, "epoch": 0,
            "resume": 0, "tree": 0,
        }
        # tree front end (§15): staged (elems, cfg, tcfg) awaiting the walk,
        # the serving side's in-flight walk state, and the outcome summary
        self._tree: tuple | None = None
        self._tree_walk: dict | None = None
        self.tree_depth = 0
        self.tree_leaves: int | None = None
        self._d_known: dict[int, int | None] = {}
        self._epoch = 0
        self._epoch_pending: dict[int, tuple] | None = None  # sid -> (set, dk)
        self._carry: dict = {}              # totals of resumed-away streams
        self.sessions_degraded = 0          # degradation-ladder escalations
        self.parity_extensions = 0          # rateless ladder levels applied
        self.verified: list[bool] | None = None

    # -- submission ------------------------------------------------------

    def _submit(self, elems, cfg: PBSConfig | None, d_known: int | None):
        cfg = cfg or PBSConfig()
        elems = np.unique(np.asarray(elems, dtype=np.uint32))
        sid = len(self._sessions)
        self._d_known[sid] = d_known
        if d_known is not None:
            self._install(sid, elems, plan_from_d_known(cfg, d_known), append=True)
        else:
            self._sessions.append(None)
            self._est_queue.append(sid)
            self._pending_store(sid, elems, cfg)
        return sid

    def _install(self, sid, elems, plan, *, append: bool):
        a, b = (elems, _EMPTY) if self.side == "a" else (_EMPTY, elems)
        sess = ReconSession(sid=sid, plan=plan, state=new_session_state(a, b, plan))
        if append:
            self._sessions.append(sess)
        else:
            self._sessions[sid] = sess
        return sess

    def _pending_store(self, sid, elems, cfg):
        raise NotImplementedError

    # -- tree front end (DESIGN.md §15) ----------------------------------

    def submit_tree(self, elems, cfg: PBSConfig | None = None,
                    tree: TreeConfig | None = None) -> None:
        """Stage this endpoint's side of a tree-phase cold start: the walk
        runs before phase 0, and every divergent leaf range becomes an
        ordinary known-d session appended after all regular submits — so
        the peer must ``submit_tree`` its matching side with the same
        ``cfg``/``tree`` (positional contract, like ``submit``)."""
        if self._tree is not None or self._tree_walk is not None:
            raise RuntimeError("a tree phase is already staged")
        if self._batch is not None:
            raise RuntimeError("tree staging after the session batch formed")
        self._tree = (
            np.unique(np.asarray(elems, dtype=np.uint32)),
            cfg or PBSConfig(),
            tree or TreeConfig(),
        )

    def _collect_leaves(self, frontier, verdicts, leaf_ds, leaves) -> None:
        li = 0
        for (lo, hi), v in zip(frontier, verdicts):
            if v == wf.TREE_LEAF:
                leaves.append(TreeLeaf(lo=lo, hi=hi, d_plan=int(leaf_ds[li])))
                li += 1

    def _install_tree_leaves(self, elems, cfg, leaves, depth: int) -> None:
        self.tree_depth = depth
        self.tree_leaves = len(leaves)
        for sub, leaf in zip(leaf_slices(elems, leaves), leaves):
            self._submit(sub, cfg, d_known=leaf.d_plan)

    # -- round machinery -------------------------------------------------

    def _ensure_batch(self) -> SessionBatch:
        if self._tree is not None or self._tree_walk is not None:
            raise WireError("round traffic before the tree phase completed")
        if self._est_queue:
            raise WireError("round traffic before phase 0 completed")
        if self._batch is None:
            self._batch = SessionBatch(
                self._sessions, sides=(self.side,),
                mutable=self._continuous, tracer=self.tracer,
            )
        return self._batch

    # -- continuous sync (DESIGN.md §11) ---------------------------------

    def advance_epoch(self, mutations: dict | None = None, *,
                      d_known: dict | None = None) -> int:
        """Stage the next epoch's sets: the initiating side folds its
        learned diff (replica convergence), then this side's local churn
        from ``mutations`` (sid -> (added, removed)) applies.  ``d_known``
        (sid -> int | None) *rebinds* a session's d convention from this
        epoch on — an int pins d for this and later epochs, ``None``
        returns the session to re-running the d̂ handshake over the wire;
        sessions not mentioned keep their current convention (initially
        the submit-time one).  The epoch itself runs on the next
        ``run_epoch``/``serve_epoch``, which patches the resident stores
        with the net delta in place.  Requires ``continuous=True`` (stores
        packed with mutation lanes).
        """
        if not self._continuous:
            raise RuntimeError("advance_epoch needs continuous=True")
        if self._est_queue or any(s is None for s in self._sessions):
            raise RuntimeError("advance_epoch before the admission epoch ran")
        if self._epoch_pending is not None:
            raise RuntimeError(f"epoch {self._epoch} is already staged")
        muts = mutations or {}
        unknown = (set(muts) | set(d_known or {})) - set(range(len(self._sessions)))
        if unknown:
            # a typo'd sid must not silently drop the caller's churn
            raise KeyError(f"unknown sid(s) {sorted(unknown)} in epoch advance")
        if d_known:
            self._d_known.update(d_known)
        self._epoch += 1
        pending: dict[int, tuple] = {}
        for s in self._sessions:
            added, removed = muts.get(s.sid, (_EMPTY, _EMPTY))
            pending[s.sid] = (
                apply_churn(self._epoch_base(s), added, removed),
                self._d_known[s.sid],
            )
        self._epoch_pending = pending
        return self._epoch

    def _epoch_base(self, sess: ReconSession) -> np.ndarray:
        """This side's set going into the next epoch, before local churn."""
        raise NotImplementedError

    def _encode_round(self, plans: list[CohortRoundPlan]) -> dict[int, _SessionRows]:
        return encode_round_rows(plans, self.side, self._interpret)

    @staticmethod
    def _schema(per: dict[int, _SessionRows], live: list[int]):
        return round_schema(per, live)

    def _expect(self, msg_type: int) -> bytes:
        got, payload = self._stream.recv()
        if got != msg_type:
            raise WireError(f"expected message 0x{msg_type:02x}, got 0x{got:02x}")
        return payload

    @property
    def sessions(self) -> list[ReconSession]:
        return self._sessions

    def _degrade_after(self, rnd: int) -> None:
        """Post-barrier degradation hook: escalate any session whose round
        budget just ran out (both endpoints call this at the same round
        with mirrored state, so their escalations agree; DESIGN.md §13)."""
        if self._degrade:
            escalated = degrade_exhausted(self._ensure_batch(), rnd)
            if escalated:
                self.sessions_degraded += len(escalated)
                self.tracer.instant("endpoint.degrade", round=rnd,
                                    sessions=len(escalated))

    @property
    def wire_stats(self) -> dict:
        """Measured wire traffic: exact framed bytes by category plus the
        transport totals (which additionally see ARQ overhead, if any).

        A derived snapshot of the ``wire.*`` metrics in the recorder —
        same keys and values as the pre-obs ad-hoc dict (DESIGN.md §14).
        """
        self.recorder.publish(
            "wire", stream_wire_stats(self._stream, self._tally, self._carry)
        )
        self.recorder.set("endpoint.resumes", getattr(self, "resumes", 0))
        self.recorder.set("endpoint.sessions_degraded", self.sessions_degraded)
        self.recorder.set("endpoint.parity_extensions", self.parity_extensions)
        return self.recorder.view("wire")


class AliceEndpoint(_Endpoint):
    """The initiating endpoint; learns A △ B for every submitted session."""

    side = "a"

    def __init__(
        self,
        transport: Transport,
        *,
        interpret: bool | None = None,
        channel: int | None = None,
        continuous: bool = False,
        degrade: bool = False,
        estimate_limit: float | None = ESTIMATE_LIMIT_FRAC,
        recorder: Recorder | None = None,
        tracer=None,
    ):
        super().__init__(transport, interpret=interpret, channel=channel,
                         continuous=continuous, degrade=degrade,
                         estimate_limit=estimate_limit,
                         recorder=recorder, tracer=tracer)
        self._pending: dict[int, tuple] = {}   # sid -> (a, cfg)
        self._fold_diff = True
        # resumption state (DESIGN.md §13): the last completed local round
        # barrier, the rolling transcript digests at that barrier and the
        # one before, the framed outcome bytes of the last barrier (replayed
        # when the hub missed them), and the per-category tally marks the
        # partial-round rollback restores on resume.
        self._rnd = 0
        self._digest = wf.transcript_digest0(0)
        self._digest_prev = self._digest
        self._last_outcome: bytes | None = None
        self._marks = {"protocol": 0, "verify": 0}
        self.resumes = 0

    def _pending_store(self, sid, elems, cfg):
        self._pending[sid] = (elems, cfg)

    def submit(self, set_a, cfg: PBSConfig | None = None, d_known: int | None = None) -> int:
        """Enqueue one session (this endpoint holds ``set_a``); the peer
        must ``submit`` the matching ``set_b`` with the same cfg/d_known in
        the same order — session identity is positional, like the paper's
        out-of-band-agreed hash functions."""
        return self._submit(set_a, cfg, d_known)

    def advance_epoch(self, mutations: dict | None = None, *,
                      d_known: dict | None = None,
                      fold_diff: bool = True) -> int:
        """Stage the next epoch (see ``_Endpoint.advance_epoch``); with
        ``fold_diff`` (the default) each session first folds its learned
        diff into A — replica convergence: A ← A △ D̂ = B — before this
        side's local churn applies."""
        self._fold_diff = fold_diff
        return super().advance_epoch(mutations, d_known=d_known)

    def _epoch_base(self, sess: ReconSession) -> np.ndarray:
        st = sess.state
        return effective_set(st.a, st.diff) if self._fold_diff else st.a

    def run_epoch(self) -> dict[int, ReconcileResult]:
        """Drive one staged epoch over the wire: the ``MSG_EPOCH``
        handshake (epoch id + d̂ re-estimation through the phase-0 codecs),
        an in-place delta patch of the resident stores, then the same
        round/verify machinery as ``run`` — per-epoch results are
        byte-identical to a fresh session over the epoch's sets."""
        if self._epoch_pending is None:
            raise RuntimeError("no epoch staged: call advance_epoch first")
        pending, self._epoch_pending = self._epoch_pending, None
        e = self._epoch
        self.tracer.instant("epoch.open", epoch=e)
        batch = self._ensure_batch()

        est_sids = [sid for sid in sorted(pending) if pending[sid][1] is None]
        sent = {}
        if est_sids:
            for sid in est_sids:
                elems, _ = pending[sid]
                cfg = self._sessions[sid].plan.cfg
                sk = tow_sketches(elems, derive_seed(cfg.seed, 0x70), cfg.ell)
                inner = wf.encode_tow_sketch(sk, len(elems))
                f = wf.encode_epoch(e, inner)
                self._stream.send(f)
                self._tally["epoch"] += len(f) - len(inner)
                sent[sid] = len(inner)
        else:
            f = wf.encode_epoch(e)
            self._stream.send(f)
            self._tally["epoch"] += len(f)

        plans = {}
        for sid in est_sids:
            payload = self._expect(wf.MSG_EPOCH)
            got_e, ity, ipayload = wf.decode_epoch(payload)
            if got_e != e:
                raise WireError(f"epoch frame for epoch {got_e} during epoch {e}")
            if ity != wf.MSG_DHAT:
                raise WireError(
                    f"expected d_hat inside the epoch reply, got {ity}"
                )
            inner_len = framed_len(len(ipayload))
            self._tally["epoch"] += _framed_len(payload) - inner_len
            est_frames = sent[sid] + inner_len
            self._tally["estimator"] += est_frames
            elems, _ = pending[sid]
            plan = plan_from_estimate(
                self._sessions[sid].plan.cfg, wf.decode_dhat(ipayload), len(elems)
            )
            if plan.est_bytes != est_frames:
                raise WireError(
                    f"sid {sid}: epoch estimator frames measure {est_frames} B, "
                    f"accounted {plan.est_bytes} B"
                )
            plans[sid] = plan
        if not est_sids:
            payload = self._expect(wf.MSG_EPOCH)
            got_e, ity, _ = wf.decode_epoch(payload)
            if got_e != e or ity is not None:
                raise WireError(f"bad epoch-open ack for epoch {e}")
            self._tally["epoch"] += _framed_len(payload)

        for sid in sorted(pending):
            elems, dk = pending[sid]
            sess = self._sessions[sid]
            plan = plans.get(sid) or plan_from_d_known(sess.plan.cfg, dk)
            advance_session(batch, sess, plan, new_a=elems, rnd0=0)
        self._reset_rounds()
        return self._run_rounds()

    def run(self) -> dict[int, ReconcileResult]:
        """Drive every session to completion over the wire; sid -> result."""
        if self._epoch_pending is not None:
            raise RuntimeError(
                f"epoch {self._epoch} is staged: call run_epoch, not run"
            )
        self._tree_phase()
        self._phase0()
        self._ensure_batch()
        self._reset_rounds()
        return self._run_rounds()

    def _tree_phase(self) -> None:
        """Drive the staged tree walk (§15): one digest->verdict barrier
        per level — one batched ``tree_digest`` launch a side — then
        install every divergent leaf range as an ordinary known-d session.
        The serving peer mirrors the frontier from the same deterministic
        split rule, so frames never ship range bounds."""
        if self._tree is None:
            return
        elems, cfg, tcfg = self._tree
        self._tree = None
        frontier: list[tuple[int, int]] = [(0, SPAN)]
        leaves: list[TreeLeaf] = []
        level = 0
        while frontier:
            with self.tracer.span("tree.level.dispatch", cat="device",
                                  level=level, ranges=len(frontier)):
                cnt, cs, sk = level_digests(
                    elems, frontier, tcfg, interpret=self._interpret
                )
                f = wf.encode_tree_digest(level, cnt, cs, sk)
                self._stream.send(f)
                self._tally["tree"] += len(f)
            with self.tracer.span("tree.level.collect", cat="wire",
                                  level=level, ranges=len(frontier)):
                payload = self._expect(wf.MSG_TREE)
                self._tally["tree"] += _framed_len(payload)
                got, verdicts, leaf_ds = wf.decode_tree_verdict(payload)
                if got != level:
                    raise WireError(
                        f"tree verdict for level {got} at level {level}"
                    )
                if len(verdicts) != len(frontier):
                    raise WireError(
                        f"tree verdict covers {len(verdicts)} ranges, "
                        f"frontier has {len(frontier)}"
                    )
                self._collect_leaves(frontier, verdicts, leaf_ds, leaves)
                frontier = split_ranges(frontier, verdicts)
            level += 1
        self._install_tree_leaves(elems, cfg, leaves, max(level - 1, 0))

    def _reset_rounds(self) -> None:
        """Re-arm the round loop and resumption state for a fresh epoch."""
        self._rnd = 0
        self._digest = wf.transcript_digest0(self._epoch)
        self._digest_prev = self._digest
        self._last_outcome = None
        self._marks = {k: self._tally[k] for k in self._marks}

    def _run_rounds(self) -> dict[int, ReconcileResult]:
        batch = self._ensure_batch()
        tracer = self.tracer
        while True:
            rnd = self._rnd + 1
            plans = batch.plan_round(rnd)
            if not plans:
                break
            with tracer.span("round.encode", cat="device", round=rnd,
                             cohorts=len(plans)):
                per = self._encode_round(plans)
            live = sorted(per)
            schema = self._schema(per, live)

            sk_frame = wf.encode_round_sketches(
                rnd, [(per[sid].sk, per[sid].plan.store.m) for sid in live]
            )
            self._stream.send(sk_frame)
            self._tally["protocol"] += len(sk_frame)

            with tracer.span("round.reply_wait", cat="wire", round=rnd,
                             sessions=len(live)):
                payload = self._expect(wf.MSG_ROUND_REPLY)
            self._tally["protocol"] += _framed_len(payload)
            got_rnd, entries = wf.decode_round_reply(payload, schema)
            if got_rnd != rnd:
                raise WireError(f"reply for round {got_rnd} during round {rnd}")

            # the measured main-reply ledger is snapshotted BEFORE the
            # rateless ladder merges extension outcomes into the entries:
            # an ext-recovered unit's positions are measured once, from the
            # extension reply that actually carried them
            measured_of = {}
            ent_of = {}
            for sid, (ok, units) in zip(live, entries):
                row = per[sid]
                u_cnt = len(row.active)
                t_, m_ = row.plan.store.t, row.plan.store.m
                measured_of[sid] = (
                    wf.sketches_ledger_bits(u_cnt, t_, m_)
                    + wf.reply_ledger_bits(ok, units, m_)
                )
                ent_of[sid] = [np.asarray(ok, dtype=bool).copy(), list(units)]
            ext_bits_of, measured_ext = self._rateless_ladder(
                rnd, plans, per, live, ent_of
            )

            done_lists = []
            for sid in live:
                ok, units = ent_of[sid]
                row = per[sid]
                st, plan = row.sess.state, row.sess.plan
                rloc = rnd - row.sess.rnd0   # local protocol round
                u_cnt = len(row.active)
                n, t, m = plan.n, plan.t, plan.m
                xors_b = np.zeros((u_cnt, n), dtype=np.uint32)
                csum_b = np.zeros(u_cnt, dtype=np.uint64)
                positions = []
                for slot in range(u_cnt):
                    unit = units[slot]
                    if unit is None:
                        positions.append(np.zeros(0, dtype=np.int64))
                        continue
                    positions.append(unit.positions)
                    xors_b[slot, unit.positions] = unit.xors
                    csum_b[slot] = unit.csum
                reply_bits, done = apply_round_outcomes(
                    st, row.active, ok, positions,
                    row.xors, xors_b, row.csum, csum_b,
                    plan=plan, bin_seed=row.bin_seed, rnd=rloc,
                )
                # the measured ledger: sketch bits from what we framed,
                # reply + parity bits from what the frames actually carried
                # — must land exactly on the Formula-(1) accounting
                measured = measured_of[sid] + measured_ext[sid]
                accounted = u_cnt * (t * m + 1) + reply_bits + ext_bits_of[sid]
                if measured != accounted:
                    raise WireError(
                        f"sid {sid} round {rnd}: measured {measured} bits != "
                        f"accounted {accounted}"
                    )
                st.bytes_per_round.append((measured + 7) // 8)
                st.rounds = rloc
                done_lists.append(done)

            out_frame = wf.encode_round_outcome(rnd, done_lists)
            # commit the barrier BEFORE the send: local state is complete, so
            # a transport failure from here on resumes by replaying this
            # frame instead of re-running the round (DESIGN.md §13)
            self._digest_prev = self._digest
            self._digest = wf.fold_transcript(self._digest, rnd, out_frame)
            self._last_outcome = out_frame
            self._rnd = rnd
            self._tally["protocol"] += len(out_frame)
            self._marks = {k: self._tally[k] for k in self._marks}
            self._stream.send(out_frame)
            tracer.instant("round.barrier", round=rnd, epoch=self._epoch)
            self._degrade_after(rnd)

        with tracer.span("verify", sessions=len(self._sessions)):
            self._verify()
        # lossy-channel tail: keep ACKing the peer's retransmits until quiet
        self._stream.transport.linger()
        results = {
            s.sid: finalize_result(s.state, s.plan) for s in self._sessions
        }
        if tracer.enabled:
            # per-session attribution for trace_report: bytes/diff/rounds
            # against the plan's (n, t, d_est) for the Markov comparison
            for sid, r in results.items():
                p = self._sessions[sid].plan
                tracer.instant(
                    "session.result", sid=sid, rounds=r.rounds,
                    diff=len(r.diff), bytes=r.bytes_sent, success=r.success,
                    n=p.n, t=p.t, g=p.g, d_est=p.d_est,
                    channel=self._stream.channel,
                )
        return results

    def _rateless_ladder(self, rnd, plans, per, live, ent_of):
        """Drive the ``MSG_PARITY`` recovery ladder for one round (§16).

        While any rateless session has units whose BCH decode failed and
        its cohort's t can still grow, ship only the incremental syndrome
        columns for the failing units and fold Bob's extension replies
        into ``ent_of`` in place — the merged entries drive the single
        ``apply_round_outcomes`` downstream, so settled units are never
        re-sent and split seeds still derive from this round.  Returns
        per-sid (accounted ext bits, measured ext bits); both stay zero on
        the honest path, which therefore remains byte-identical to the
        ``rateless=False`` wire format.
        """
        ext_bits = {sid: 0 for sid in live}
        measured = {sid: 0 for sid in live}
        fail: dict[int, list[int]] = {}
        for sid in live:
            row = per[sid]
            if not row.sess.plan.cfg.rateless:
                continue
            bad = [s for s in range(len(row.active)) if not ent_of[sid][0][s]]
            if bad:
                fail[sid] = bad
        for level in range(1, MAX_PARITY_EXTENSIONS + 1):
            if not fail:
                break
            part_plans = [
                plan for plan in plans
                if any(sess.sid in fail for sess, *_ in plan.members)
            ]
            inc_of = encode_round_rows_ext(
                part_plans, self.side, level, self._interpret
            )
            parts = [sid for sid in live if sid in fail and sid in inc_of]
            if not parts:
                break  # every failing cohort hit the (n-1)//2 code cap
            blocks = []
            reply_schema = []
            for sid in parts:
                inc, t0, t1 = inc_of[sid]
                m = per[sid].plan.store.m
                blocks.append((inc[fail[sid]], m))
                reply_schema.append((len(fail[sid]), t1, m))
            pf = wf.encode_parity(rnd, level, blocks)
            self._stream.send(pf)
            self._tally["protocol"] += len(pf)
            payload = self._expect(wf.MSG_ROUND_REPLY)
            self._tally["protocol"] += _framed_len(payload)
            got_rnd, ext_entries = wf.decode_round_reply(payload, reply_schema)
            if got_rnd != rnd:
                raise WireError(
                    f"extension reply for round {got_rnd} during round {rnd}"
                )
            for sid, (ok_e, units_e) in zip(parts, ext_entries):
                _, t0, t1 = inc_of[sid]
                m = per[sid].plan.store.m
                slots = fail[sid]
                ext_bits[sid] += len(slots) * ((t1 - t0) * m + 1)
                measured[sid] += wf.parity_ledger_bits(len(slots), t1 - t0, m)
                measured[sid] += wf.reply_ledger_bits(ok_e, units_e, m)
                self.parity_extensions += 1
                self.tracer.instant(
                    "endpoint.parity_extension", sid=sid, round=rnd,
                    level=level, units=len(slots), t=t1,
                )
                ok_m, units_m = ent_of[sid]
                still = []
                for i, slot in enumerate(slots):
                    if ok_e[i]:
                        ok_m[slot] = True
                        units_m[slot] = units_e[i]
                    else:
                        still.append(slot)
                if still:
                    fail[sid] = still
                else:
                    del fail[sid]
        return ext_bits, measured

    def resume(self, transport: Transport) -> None:
        """Reconnect to the hub over a fresh transport after a failure and
        re-align at the last completed round barrier (DESIGN.md §13).

        Rolls any partial-round frame bytes out of the protocol/verify
        tallies into the resume tally (the aborted attempt re-runs, so the
        Formula-(1) ledger must count it exactly once), then runs the
        ``MSG_RESUME`` handshake: we announce our last completed barrier
        and transcript digests; the hub answers with its mirror's barrier.
        Equal barriers must agree on ``digest``; a hub exactly one barrier
        behind (our last outcome frame died in flight) must agree on
        ``digest_prev`` and gets that frame replayed — it applies it
        idempotently from its retained round context.  Anything else means
        divergence or an unresumable peer and raises.  Follow with
        ``resume_run()`` to drive the protocol to completion.
        """
        if self._stream.channel is None:
            raise RuntimeError("resume needs a hub channel-tagged stream")
        if self._last_outcome is None and self._rnd:
            raise RuntimeError("resume before any round barrier completed")
        with self.tracer.span("resume", channel=self._stream.channel,
                              epoch=self._epoch, barrier=self._rnd):
            self._resume(transport)

    def _resume(self, transport: Transport) -> None:
        for cat, mark in self._marks.items():
            spill = self._tally[cat] - mark
            if spill:
                self._tally[cat] = mark
                self._tally["resume"] += spill
        old = self._stream
        t_old = old.transport
        self._carry = {
            "transport_bytes_out": t_old.bytes_out
            + self._carry.get("transport_bytes_out", 0),
            "transport_bytes_in": t_old.bytes_in
            + self._carry.get("transport_bytes_in", 0),
            "retransmits": getattr(t_old, "retransmits", 0)
            + self._carry.get("retransmits", 0),
        }
        stream = FrameStream(transport, channel=old.channel)
        stream.frames_out, stream.frames_in = old.frames_out, old.frames_in
        stream.bytes_out, stream.bytes_in = old.bytes_out, old.bytes_in
        stream.mux_bytes_out = old.mux_bytes_out
        stream.mux_bytes_in = old.mux_bytes_in
        self._stream = stream

        f = wf.encode_resume(
            stream.channel, self._epoch, self._rnd,
            self._digest, self._digest_prev,
        )
        self._stream.send(f)
        payload = self._expect(wf.MSG_RESUME)
        self._tally["resume"] += len(f) + _framed_len(payload)
        ch, epoch, hub_rnd, hub_digest, _ = wf.decode_resume(payload)
        if ch != stream.channel or epoch != self._epoch:
            raise WireError(
                f"resume answer for channel {ch} epoch {epoch}, "
                f"expected channel {stream.channel} epoch {self._epoch}"
            )
        if hub_rnd == self._rnd:
            if hub_digest != self._digest:
                raise WireError("resume transcript diverged at equal barriers")
        elif hub_rnd == self._rnd - 1 and self._last_outcome is not None:
            if hub_digest != self._digest_prev:
                raise WireError("resume transcript diverged one barrier back")
            # the hub missed our last outcome barrier: replay it verbatim
            self._stream.send(self._last_outcome)
            self._tally["resume"] += len(self._last_outcome)
        else:
            raise WireError(
                f"unresumable: hub barrier {hub_rnd}, ours {self._rnd}"
            )
        self.resumes += 1

    def resume_run(self) -> dict[int, ReconcileResult]:
        """Continue a resumed protocol from the re-aligned barrier to
        completion — the round loop picks up at ``self._rnd + 1`` over the
        intact session states and cohort stores."""
        return self._run_rounds()

    def _phase0(self):
        if not self._est_queue:
            return
        with self.tracer.span("phase0", sessions=len(self._est_queue)):
            self._phase0_exchange()

    def _phase0_exchange(self):
        sent = {}
        for sid in self._est_queue:
            a, cfg = self._pending[sid]
            sk = tow_sketches(a, derive_seed(cfg.seed, 0x70), cfg.ell)
            f = wf.encode_tow_sketch(sk, len(a))
            self._stream.send(f)
            sent[sid] = len(f)
        for sid in list(self._est_queue):
            a, cfg = self._pending.pop(sid)
            payload = self._expect(wf.MSG_DHAT)
            num = wf.decode_dhat(payload)
            est_frames = sent[sid] + _framed_len(payload)
            self._tally["estimator"] += est_frames
            plan = plan_from_estimate(cfg, num, len(a))
            if plan.est_bytes != est_frames:
                raise WireError(
                    f"sid {sid}: estimator frames measure {est_frames} B, "
                    f"accounted {plan.est_bytes} B"
                )
            self._install(sid, a, plan, append=False)
        self._est_queue.clear()

    def _verify(self):
        entries = []
        for s in self._sessions:
            success = all(u.done for u in s.state.units)
            entries.append(
                (success, checksum(effective_set(s.state.a, s.state.diff)))
            )
        f = wf.encode_verify(entries)
        self._stream.send(f)
        self._tally["verify"] += len(f)
        payload = self._expect(wf.MSG_VERIFY_ACK)
        self._tally["verify"] += _framed_len(payload)
        self.verified = wf.decode_verify_ack(payload, len(self._sessions))


class BobEndpoint(_Endpoint):
    """The serving endpoint; holds the B sets and answers frames until the
    final verification exchange, mirroring every session's unit queue."""

    side = "b"

    def __init__(
        self,
        transport: Transport,
        *,
        interpret: bool | None = None,
        channel: int | None = None,
        continuous: bool = False,
        degrade: bool = False,
        estimate_limit: float | None = ESTIMATE_LIMIT_FRAC,
        recorder: Recorder | None = None,
        tracer=None,
    ):
        super().__init__(transport, interpret=interpret, channel=channel,
                         continuous=continuous, degrade=degrade,
                         estimate_limit=estimate_limit,
                         recorder=recorder, tracer=tracer)
        self._pending: dict[int, tuple] = {}   # sid -> (b, cfg)
        self._rnd = 0                          # rounds whose sketches arrived
        self._ctx = None                       # current round's (live, per-sid)
        self._epoch_plans: dict[int, object] = {}

    def _pending_store(self, sid, elems, cfg):
        self._pending[sid] = (elems, cfg)

    def _epoch_base(self, sess: ReconSession) -> np.ndarray:
        return sess.state.b

    def submit(self, set_b, cfg: PBSConfig | None = None, d_known: int | None = None) -> int:
        """Enqueue this endpoint's side of the next session (positional
        pairing with the peer's ``submit`` order)."""
        return self._submit(set_b, cfg, d_known)

    def serve_epoch(self) -> None:
        """Serve one staged epoch: the peer's ``MSG_EPOCH`` handshake
        (validated against the locally staged epoch id), the in-place
        store delta patch, then frames until the epoch's verification
        exchange completes."""
        if self._epoch_pending is None:
            raise RuntimeError("no epoch staged: call advance_epoch first")
        self.serve()

    def serve(self) -> None:
        """Answer frames until the verification exchange completes."""
        while True:
            msg_type, payload = self._stream.recv()
            if msg_type == wf.MSG_TREE:
                self._handle_tree(payload)
            elif msg_type == wf.MSG_TOW_SKETCH:
                self._handle_tow(payload)
            elif msg_type == wf.MSG_EPOCH:
                self._handle_epoch(payload)
            elif msg_type == wf.MSG_ROUND_SKETCHES:
                self._handle_sketches(payload)
            elif msg_type == wf.MSG_PARITY:
                self._handle_parity(payload)
            elif msg_type == wf.MSG_ROUND_OUTCOME:
                self._handle_outcome(payload)
            elif msg_type == wf.MSG_VERIFY:
                self._handle_verify(payload)
                return
            else:
                raise WireError(f"unexpected message type 0x{msg_type:02x}")

    def _handle_tree(self, payload: bytes) -> None:
        """Answer one level of the peer's tree walk (§15) through the
        shared ``serve_tree_frame``; when the deterministic split rule
        empties the frontier, install the accumulated leaf sessions."""
        if self._tree_walk is None:
            if self._tree is None:
                raise WireError("tree frame with no tree phase staged")
            elems, cfg, tcfg = self._tree
            self._tree = None
            self._tree_walk = tree_walk_state(elems, cfg, tcfg)
        w = self._tree_walk
        if serve_tree_frame(payload, w, self._stream, self._tally,
                            self.tracer, self._interpret):
            self._tree_walk = None
            self._install_tree_leaves(
                w["elems"], w["cfg"], w["leaves"], w["level"] - 1
            )

    def _handle_epoch(self, payload: bytes) -> None:
        """One step of the peer's epoch handshake (the shared
        ``serve_epoch_frame`` state machine); once every staged session
        has its plan, fold the epoch in: delta-patch the resident store
        and reset the round state machine."""
        if self._epoch_pending is None:
            raise WireError("epoch frame with no epoch advance staged")
        done = serve_epoch_frame(
            payload, self._epoch, self._epoch_pending, self._epoch_plans,
            lambda sid: self._sessions[sid].plan.cfg,
            self._stream, self._tally, self._estimate_limit,
        )
        if done:
            self._install_epoch()

    def _install_epoch(self) -> None:
        batch = self._ensure_batch()
        pending, self._epoch_pending = self._epoch_pending, None
        for sid in sorted(pending):
            elems, dk = pending[sid]
            sess = self._sessions[sid]
            plan = self._epoch_plans.get(sid) or plan_from_d_known(
                sess.plan.cfg, dk
            )
            advance_session(batch, sess, plan, new_b=elems, rnd0=0)
        self._epoch_plans = {}
        self._rnd = 0
        self._ctx = None

    def _handle_tow(self, payload: bytes) -> None:
        if not self._est_queue:
            raise WireError("ToW sketch frame with no estimator session pending")
        sid = self._est_queue.pop(0)
        b, cfg = self._pending.pop(sid)
        reply, plan, est_bytes = serve_phase0(
            payload, b, cfg, self._estimate_limit
        )
        self._stream.send(reply)
        self._tally["estimator"] += est_bytes
        self._install(sid, b, plan, append=False)

    def _handle_sketches(self, payload: bytes) -> None:
        if self._ctx is not None:
            raise WireError("sketch frame while a round outcome is pending")
        if self._epoch_pending is not None:
            raise WireError("round traffic before the staged epoch handshake")
        batch = self._ensure_batch()
        rnd = self._rnd + 1
        plans = batch.plan_round(rnd)
        with self.tracer.span("round.encode", cat="device", round=rnd,
                              cohorts=len(plans)):
            per = self._encode_round(plans)
        live = sorted(per)
        schema = self._schema(per, live)
        got_rnd, blocks = wf.decode_round_sketches(payload, schema)
        if got_rnd != rnd:
            raise WireError(f"sketch frame for round {got_rnd}, expected {rnd}")
        self._rnd = rnd
        self._tally["protocol"] += _framed_len(payload)

        # per cohort: place each session's frame sketches at its row slice,
        # XOR with our device-resident side, decode every unit at once
        # (padding rows carry zero sketches on both sides: trivially ok)
        with self.tracer.span("round.decode", cat="device", round=rnd,
                              sessions=len(live)):
            results, ctx = decode_side_b_round(
                plans, per, dict(zip(live, blocks))
            )
        reply = wf.encode_round_reply(rnd, [results[sid] for sid in live], schema)
        self._stream.send(reply)
        self._tally["protocol"] += len(reply)
        # rateless ladder state (§16): the failing slots of every rateless
        # session, plus everything a MSG_PARITY extension needs to re-decode
        # this round's bitmaps at a wider t — cached frame sketches (the
        # prefix), our row slices, and the cohort plans.
        fail: dict[int, list[int]] = {}
        for sid in live:
            sess, active, ok, _ = ctx[sid]
            if not sess.plan.cfg.rateless:
                continue
            bad = [s for s in range(len(active)) if not ok[s]]
            if bad:
                fail[sid] = bad
        self._ctx = {
            "live": live, "ctx": ctx, "per": per, "plans": plans,
            "sk_a": dict(zip(live, blocks)), "fail": fail, "level": 0,
            "acc": {},
        }

    def _handle_parity(self, payload: bytes) -> None:
        """Serve one ``MSG_PARITY`` rateless extension (DESIGN.md §16).

        XOR Alice's incremental syndrome columns with our own side's, grow
        each failing unit's cached round-diff prefix, re-decode per cohort
        in one batched launch at the extended t, and reply with the
        extension outcomes through the ordinary round-reply codec.  The
        round context's ``ok`` arrays are merged in place, so the outcome
        frame (and any resume replay) sees the post-ladder verdicts.
        """
        c = self._ctx
        if c is None:
            raise WireError("parity frame with no round in flight")
        fail = c["fail"]
        level = c["level"] + 1
        if level > MAX_PARITY_EXTENSIONS:
            raise WireError(f"parity frame beyond the level-{level - 1} cap")
        part_plans = [
            plan for plan in c["plans"]
            if any(sess.sid in fail for sess, *_ in plan.members)
        ]
        inc_of = encode_round_rows_ext(
            part_plans, self.side, level, self._interpret
        )
        parts = [sid for sid in c["live"] if sid in fail and sid in inc_of]
        if not parts:
            raise WireError("unexpected parity frame: no extension pending")
        schema = [
            (len(fail[sid]), inc_of[sid][2] - inc_of[sid][1],
             c["per"][sid].plan.store.m)
            for sid in parts
        ]
        # reply schema before the merge loop mutates ``fail``: the ext
        # reply covers every unit that was failing at this level, at t1
        reply_schema = [
            (len(fail[sid]), inc_of[sid][2], c["per"][sid].plan.store.m)
            for sid in parts
        ]
        got_rnd, got_level, blocks = wf.decode_parity(payload, schema)
        if got_rnd != self._rnd:
            raise WireError(
                f"parity frame for round {got_rnd}, expected {self._rnd}"
            )
        if got_level != level:
            raise WireError(
                f"parity frame at level {got_level}, expected {level}"
            )
        self._tally["protocol"] += _framed_len(payload)

        # grow each failing unit's accumulated diff syndromes: prefix
        # (frame sketch ^ our sketch, cached at decode time) + increments
        acc = c["acc"]
        for sid, inc_a in zip(parts, blocks):
            inc_b = inc_of[sid][0]
            prefix_a = c["sk_a"][sid]
            sk_b = c["per"][sid].sk
            slot_acc = acc.setdefault(sid, {})
            for i, slot in enumerate(fail[sid]):
                prev = slot_acc.get(slot)
                if prev is None:
                    prev = np.asarray(prefix_a[slot], dtype=np.int64) ^ np.asarray(
                        sk_b[slot], dtype=np.int64
                    )
                d = np.asarray(inc_a[i], dtype=np.int64) ^ np.asarray(
                    inc_b[slot], dtype=np.int64
                )
                slot_acc[slot] = np.concatenate([prev, d])

        # one batched decode per cohort: failing rows scattered into a
        # padded buffer, settled rows stay zero (trivially ok, ignored)
        entries: dict[int, tuple] = {}
        for plan in part_plans:
            n, t = plan.store.n, plan.store.t
            t1 = parity_extension_t(t, level, n)
            if t1 <= parity_extension_t(t, level - 1, n):
                continue
            u_pad = plan.arrays["row_map"].shape[0]
            buf = np.zeros((u_pad, t1), dtype=np.int64)
            hit = False
            for sess, base, active, _ in plan.members:
                if sess.sid not in parts:
                    continue
                for slot in fail[sess.sid]:
                    buf[base + slot] = acc[sess.sid][slot]
                    hit = True
            if not hit:
                continue
            ok_p, pos_p, cnt_p = (
                np.asarray(x) for x in jax.device_get(
                    bch_decode_batched(
                        jnp.asarray(buf, dtype=jnp.int32), n=n, t=t1
                    )
                )
            )
            for sess, base, active, _ in plan.members:
                sid = sess.sid
                if sid not in parts:
                    continue
                row = c["per"][sid]
                ok_m = c["ctx"][sid][2]
                ok_e, units, still = [], [], []
                for slot in fail[sid]:
                    if ok_p[base + slot]:
                        k = int(cnt_p[base + slot])
                        p = pos_p[base + slot, :k].astype(np.int64)
                        units.append(
                            ReplyUnit(
                                positions=p,
                                xors=row.xors[slot, p],
                                csum=int(row.csum[slot]),
                            )
                        )
                        ok_e.append(True)
                        ok_m[slot] = True   # in-place: outcome/resume see it
                    else:
                        units.append(None)
                        ok_e.append(False)
                        still.append(slot)
                entries[sid] = (ok_e, units)
                if still:
                    fail[sid] = still
                else:
                    del fail[sid]
                self.parity_extensions += 1
        c["level"] = level
        reply = wf.encode_round_reply(
            self._rnd, [entries[sid] for sid in parts], reply_schema
        )
        self._stream.send(reply)
        self._tally["protocol"] += len(reply)

    def _handle_outcome(self, payload: bytes) -> None:
        if self._ctx is None:
            raise WireError("outcome frame with no round in flight")
        live, ctx = self._ctx["live"], self._ctx["ctx"]
        self._ctx = None
        rnd = self._rnd
        got_rnd, done_lists = wf.decode_round_outcome(
            payload, [len(ctx[sid][1]) for sid in live]
        )
        if got_rnd != rnd:
            raise WireError(f"outcome frame for round {got_rnd}, expected {rnd}")
        self._tally["protocol"] += _framed_len(payload)
        for sid, done in zip(live, done_lists):
            sess, active, ok, _ = ctx[sid]
            rloc = rnd - sess.rnd0       # local protocol round
            for slot, u in enumerate(active):
                if not ok[slot]:
                    # our decode failed: mirror Alice's 3-way split verbatim
                    queue_split(sess.state, u, rloc, sess.plan.cfg.seed)
                elif done[slot]:
                    u.done = True
            sess.state.rounds = rloc
        self._degrade_after(rnd)

    def _handle_verify(self, payload: bytes) -> None:
        # Alice's A △ D̂ must sum to our B when she really learned A △ B
        ack, flags = verify_ack_entries(payload, self._sessions)
        self._tally["verify"] += _framed_len(payload)
        self._stream.send(ack)
        self._tally["verify"] += len(ack)
        self.verified = flags


def _framed_len(payload: bytes) -> int:
    """Exact framed size of a received payload (envelope + type + body)."""
    return framed_len(len(payload))


def _drive_pair(alice, bob, alice_call, bob_call) -> dict[int, ReconcileResult]:
    """Run one Alice step against one Bob step on a worker thread, with
    Bob's root-cause exception taking precedence (see ``run_pair``)."""
    err: list[BaseException] = []

    def _serve():
        try:
            bob_call()
        except BaseException as e:  # noqa: BLE001 - relayed to the caller
            err.append(e)
            bob._stream.transport.close()  # unblock the peer's recv

    th = threading.Thread(target=_serve, name="bob-endpoint", daemon=True)
    th.start()
    try:
        results = alice_call()
    except BaseException:
        th.join(timeout=5.0)
        if err:
            raise err[0]  # Bob's failure is the root cause, not Alice's
        raise
    th.join(timeout=60.0)
    if err:
        raise err[0]
    return results


def run_pair(alice: AliceEndpoint, bob: BobEndpoint) -> dict[int, ReconcileResult]:
    """Drive a connected endpoint pair to completion: Bob serves on a
    worker thread, Alice runs on the caller's; Bob's exceptions re-raise.

    A failing serve() closes Bob's transport so a blocked Alice fails fast
    instead of sitting out her recv timeout, and Bob's root-cause exception
    takes precedence over the secondary transport error Alice then sees.
    """
    return _drive_pair(alice, bob, alice.run, bob.serve)


def run_pair_epoch(alice: AliceEndpoint, bob: BobEndpoint) -> dict[int, ReconcileResult]:
    """Drive one staged continuous-sync epoch over a connected pair (both
    sides must have called ``advance_epoch``); same threading and error
    semantics as ``run_pair``."""
    return _drive_pair(alice, bob, alice.run_epoch, bob.serve_epoch)
