"""Transports the PBS endpoints exchange encoded bytes over (DESIGN.md §9).

Three concrete transports, one reliability wrapper, one framing helper:

* ``InMemoryDuplex`` — a thread-safe in-process pipe pair; the default for
  tests and the wire-byte measurement path in benchmarks.
* ``SocketTransport`` / ``tcp_loopback_pair`` — a real TCP connection over
  127.0.0.1; what the CI end-to-end job drives.
* ``SimulatedChannel`` — datagram semantics with configurable loss
  probability and one-way latency.  Lossy by construction, so endpoints
  must run it under ``ReliableTransport``.
* ``ReliableTransport`` — stop-and-wait ARQ (seq + ack + retransmit timer
  + duplicate suppression) turning a lossy datagram channel back into a
  reliable one; ``retransmits`` counts the recoveries.
* ``FrameStream`` — varint length-framing over any reliable transport:
  accumulates stream chunks and yields whole ``repro.wire`` frames.

Every transport counts ``bytes_out``/``bytes_in``, so tests can assert the
measured wire traffic of a full reconciliation, including ARQ overhead.
"""
from __future__ import annotations

import socket
import threading
import time
from collections import deque

import numpy as np

from repro.obs.trace import NULL_TRACER
from repro.wire import frames as wire_frames
from repro.wire.frames import WireError, split_frame
from repro.wire.varint import decode_uvarint, encode_uvarint, framed_len

_UNSET = object()  # sentinel: FrameStream.recv falls back to its default timeout


class TransportError(Exception):
    """Transport failure: closed peer, timeout, or retry exhaustion."""


class TransportTimeout(TransportError):
    """A ``recv`` deadline elapsed with no data.

    Distinct from other ``TransportError``s so pollers (the hub's
    round-barrier loop) can tell "nothing arrived yet" from "peer is gone":
    a timeout keeps the peer's deadline clock running, any other transport
    failure evicts immediately.
    """


class Transport:
    """Reliable duplex byte channel; concrete classes fill send/recv."""

    def __init__(self) -> None:
        self.bytes_out = 0
        self.bytes_in = 0

    def send(self, data: bytes) -> None:
        raise NotImplementedError

    def recv(self, timeout: float | None = None) -> bytes:
        """One inbound chunk (stream segment or datagram); blocks until
        available.  ``timeout`` None = block forever; raises TransportError
        on timeout or closed-and-drained peer."""
        raise NotImplementedError

    def linger(self, budget: float | None = None) -> None:
        """Service the channel briefly after the last expected message.

        No-op for inherently reliable transports.  An ARQ layer overrides
        this to keep acknowledging retransmitted tails (the peer's final
        datagram whose ack was lost) until the channel goes quiet —
        otherwise the peer's last reliable ``send`` can never complete.
        ``budget`` caps the whole linger window regardless of traffic.
        """

    def close(self) -> None:
        pass


class InMemoryDuplex(Transport):
    """In-process duplex pipe; ``pair()`` returns the two connected ends."""

    def __init__(self) -> None:
        super().__init__()
        self._rx: deque[bytes] = deque()
        self._cond = threading.Condition()
        self._closed = False
        self.peer: InMemoryDuplex | None = None

    @classmethod
    def pair(cls) -> tuple["InMemoryDuplex", "InMemoryDuplex"]:
        one, two = cls(), cls()
        one.peer, two.peer = two, one
        return one, two

    def _deliver(self, data: bytes) -> None:
        with self._cond:
            self._rx.append(data)
            self._cond.notify_all()

    def send(self, data: bytes) -> None:
        if self.peer is None or self.peer._closed:
            raise TransportError("send on closed in-memory pipe")
        self.bytes_out += len(data)
        self.peer._deliver(bytes(data))

    def recv(self, timeout: float | None = None) -> bytes:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while not self._rx:
                # either end closing ends the conversation once drained
                if self._closed or (self.peer is not None and self.peer._closed):
                    raise TransportError("recv on closed in-memory pipe")
                wait = None if deadline is None else deadline - time.monotonic()
                if wait is not None and wait <= 0:
                    raise TransportTimeout("in-memory recv timeout")
                self._cond.wait(wait)
            data = self._rx.popleft()
        self.bytes_in += len(data)
        return data

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self.peer is not None:
            with self.peer._cond:       # wake a peer blocked in recv
                self.peer._cond.notify_all()


class SocketTransport(Transport):
    """A connected stream socket as a Transport."""

    def __init__(self, sock: socket.socket) -> None:
        super().__init__()
        self._sock = sock

    def send(self, data: bytes) -> None:
        try:
            self._sock.sendall(data)
        except OSError as e:
            raise TransportError(f"socket send failed: {e}") from e
        self.bytes_out += len(data)

    def recv(self, timeout: float | None = None) -> bytes:
        self._sock.settimeout(timeout)
        try:
            data = self._sock.recv(65536)
        except socket.timeout as e:
            raise TransportTimeout("socket recv timeout") from e
        except OSError as e:
            raise TransportError(f"socket recv failed: {e}") from e
        if not data:
            raise TransportError("socket closed by peer")
        self.bytes_in += len(data)
        return data

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


def tcp_loopback_pair() -> tuple[SocketTransport, SocketTransport]:
    """A real TCP connection over 127.0.0.1 (ephemeral port)."""
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.bind(("127.0.0.1", 0))
    listener.listen(1)
    client = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    client.connect(listener.getsockname())
    server, _ = listener.accept()
    listener.close()
    for s in (client, server):
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    return SocketTransport(client), SocketTransport(server)


class SimulatedChannel(Transport):
    """Datagram channel with loss probability and one-way latency.

    Each ``send`` is one datagram: dropped with probability ``loss``
    (deterministic per ``seed``), otherwise delivered after ``latency``
    seconds.  Unreliable by design — wrap both ends in
    ``ReliableTransport`` to force the retransmit path.
    """

    def __init__(self, loss: float = 0.0, latency: float = 0.0, seed: int = 0) -> None:
        super().__init__()
        self._loss = float(loss)
        self._latency = float(latency)
        self._rng = np.random.default_rng(seed)
        self._rx: deque[tuple[float, bytes]] = deque()  # (ready_time, data)
        self._cond = threading.Condition()
        self._closed = False
        self.peer: SimulatedChannel | None = None
        self.dropped = 0

    @classmethod
    def pair(
        cls, loss: float = 0.0, latency: float = 0.0, seed: int = 0
    ) -> tuple["SimulatedChannel", "SimulatedChannel"]:
        one = cls(loss, latency, seed)
        two = cls(loss, latency, seed + 1)
        one.peer, two.peer = two, one
        return one, two

    def send(self, data: bytes) -> None:
        peer = self.peer
        if self._closed or peer is None or peer._closed:
            raise TransportError("send on closed simulated channel")
        self.bytes_out += len(data)
        if self._rng.random() < self._loss:
            self.dropped += 1
            return
        ready = time.monotonic() + self._latency
        with peer._cond:
            peer._rx.append((ready, bytes(data)))
            peer._cond.notify_all()

    def recv(self, timeout: float | None = None) -> bytes:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                now = time.monotonic()
                if self._rx and self._rx[0][0] <= now:
                    _, data = self._rx.popleft()
                    self.bytes_in += len(data)
                    return data
                # either end closing ends the conversation; datagrams already
                # in flight (scheduled but not ready) still deliver first
                if self._closed or (
                    self.peer is not None and self.peer._closed and not self._rx
                ):
                    raise TransportError("recv on closed simulated channel")
                wait = self._rx[0][0] - now if self._rx else None
                if deadline is not None:
                    remain = deadline - now
                    if remain <= 0:
                        raise TransportTimeout("simulated channel recv timeout")
                    wait = remain if wait is None else min(wait, remain)
                self._cond.wait(wait)

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self.peer is not None:
            with self.peer._cond:       # wake a peer blocked in recv
                self.peer._cond.notify_all()


_DATA, _ACK = 0x00, 0x01


class ReliableTransport(Transport):
    """Stop-and-wait ARQ over an unreliable datagram transport.

    Datagram layout: ``kind byte (DATA/ACK) || uvarint(seq) || payload``.
    ``send`` retransmits until the matching ACK arrives (handling any DATA
    that lands in between); ``recv`` ACKs every DATA datagram and
    suppresses duplicates by sequence number.

    The retransmit timer is adaptive (DESIGN.md §13): each attempt waits
    the current RTO (initially ``timeout``), backing off by ``backoff``
    per retransmission up to ``rto_max`` with seeded ±``jitter``
    randomization so synchronized peers decorrelate their retry storms; a
    delivered ACK resets the timer.  ``max_retries`` caps attempts per
    datagram.  A non-timeout channel failure (closed pipe) aborts the send
    immediately instead of burning the attempt budget.  ``retransmits``
    counts recoveries and ``rto_ms`` exposes the live timer — both
    surfaced through the endpoint ``wire_stats()``.
    """

    def __init__(
        self,
        channel: Transport,
        *,
        timeout: float = 0.05,
        max_retries: int = 200,
        rto_max: float = 0.4,
        backoff: float = 2.0,
        jitter: float = 0.1,
        seed: int = 0,
        tracer=None,
    ) -> None:
        super().__init__()
        self._ch = channel
        # per-datagram tracing is hot-path: every site below checks
        # ``_tracer.enabled`` first so the disabled default costs one
        # attribute read per send/recv (DESIGN.md §14)
        self._tracer = tracer if tracer is not None else NULL_TRACER
        self._timeout = float(timeout)
        self._max_retries = int(max_retries)
        self._rto_max = max(float(rto_max), float(timeout))
        self._backoff = float(backoff)
        self._jitter = float(jitter)
        self._rng = np.random.default_rng(seed)
        self._rto = self._timeout
        self._tx_seq = 0
        self._rx_next = 0
        self._ready: deque[bytes] = deque()
        self.retransmits = 0

    @property
    def rto_ms(self) -> float:
        """Current retransmit timeout in milliseconds (pre-jitter)."""
        return self._rto * 1e3

    def _attempt_wait(self) -> float:
        """One attempt's ACK wait: the current RTO with ±jitter applied."""
        if self._jitter <= 0.0:
            return self._rto
        spread = self._jitter * (2.0 * float(self._rng.random()) - 1.0)
        return self._rto * (1.0 + spread)

    def _handle(self, dgram: bytes, want_ack: int | None) -> bool:
        """Process one inbound datagram; True iff it ACKs ``want_ack``."""
        if not dgram:
            raise TransportError("empty datagram")
        kind = dgram[0]
        seq, off = decode_uvarint(dgram, 1)
        if kind == _ACK:
            return want_ack is not None and seq == want_ack
        if kind != _DATA:
            raise TransportError(f"unknown datagram kind {kind}")
        self._ch.send(bytes((_ACK,)) + encode_uvarint(seq))
        if seq == self._rx_next:       # new in-order data; dupes just re-ACK
            self._rx_next += 1
            self._ready.append(dgram[off:])
        return False

    def send(self, data: bytes) -> None:
        seq = self._tx_seq
        self._tx_seq += 1
        dgram = bytes((_DATA,)) + encode_uvarint(seq) + bytes(data)
        self.bytes_out += len(data)
        if self._tracer.enabled:
            with self._tracer.span("arq.send", cat="arq", seq=seq,
                                   bytes=len(data)):
                return self._send_arq(seq, dgram)
        return self._send_arq(seq, dgram)

    def _send_arq(self, seq: int, dgram: bytes) -> None:
        for attempt in range(self._max_retries):
            self._ch.send(dgram)
            if attempt:
                self.retransmits += 1
                if self._tracer.enabled:
                    self._tracer.instant("arq.retransmit", cat="arq", seq=seq,
                                         attempt=attempt, rto_ms=self.rto_ms)
            deadline = time.monotonic() + self._attempt_wait()
            while True:
                remain = deadline - time.monotonic()
                if remain <= 0:
                    break
                try:
                    inbound = self._ch.recv(timeout=remain)
                except TransportTimeout:
                    break
                if self._handle(inbound, want_ack=seq):
                    self._rto = self._timeout      # delivery: reset the timer
                    return
            self._rto = min(self._rto_max, self._rto * self._backoff)
        raise TransportError(f"no ACK for seq {seq} after {self._max_retries} tries")

    def recv(self, timeout: float | None = None) -> bytes:
        if self._tracer.enabled:
            with self._tracer.span("arq.recv", cat="arq"):
                return self._recv_arq(timeout)
        return self._recv_arq(timeout)

    def _recv_arq(self, timeout: float | None) -> bytes:
        deadline = None if timeout is None else time.monotonic() + timeout
        while not self._ready:
            remain = None if deadline is None else deadline - time.monotonic()
            if remain is not None and remain <= 0:
                raise TransportTimeout("reliable recv timeout")
            self._handle(self._ch.recv(timeout=remain), want_ack=None)
        data = self._ready.popleft()
        self.bytes_in += len(data)
        return data

    def linger(self, budget: float | None = None) -> None:
        """Re-ACK retransmitted tails until the channel stays quiet for a
        full backed-off retransmit window (the two-army tail: our ACK of
        the peer's last datagram may have been lost while we no longer
        expect data).  The quiet window covers the peer's maximum RTO plus
        jitter, else a backed-off peer would retransmit into a dead
        channel; ``budget`` caps the whole linger regardless of traffic so
        a babbling peer cannot hold close open forever."""
        quiet = self._rto_max * (1.0 + self._jitter) + 4 * self._timeout
        if budget is None:
            budget = 16 * quiet
        deadline = time.monotonic() + budget
        while True:
            remain = deadline - time.monotonic()
            if remain <= 0:
                return
            try:
                self._handle(
                    self._ch.recv(timeout=min(quiet, remain)), want_ack=None
                )
            except TransportError:
                return

    def close(self) -> None:
        self._ch.close()


class FrameStream:
    """Varint-framed ``repro.wire`` messages over a reliable Transport.

    Counts protocol frames and their exact framed byte sizes in each
    direction — the measured quantities the endpoint wire ledgers and the
    benchmark's bytes-per-diff gate are built from.

    With ``channel`` set (hub multiplexing, DESIGN.md §10), every outbound
    frame is wrapped in a ``MSG_MUX`` envelope tagged with that channel id
    and every inbound frame must arrive so wrapped with the *same* id — a
    missing envelope or any other id (unknown, stale, zero) raises
    ``WireError``.  Byte counters keep ledger semantics: ``bytes_out`` /
    ``bytes_in`` count the *inner* framed bytes (what the protocol ledger
    sees); the envelope's extra bytes accrue to ``mux_bytes_out`` /
    ``mux_bytes_in`` — transport-level overhead, exactly like ARQ bytes.
    """

    def __init__(
        self,
        transport: Transport,
        *,
        recv_timeout: float | None = 60.0,
        channel: int | None = None,
    ):
        self.transport = transport
        self.channel = channel
        self._buf = bytearray()
        self._off = 0
        self._recv_timeout = recv_timeout
        self.frames_out = 0
        self.frames_in = 0
        self.bytes_out = 0
        self.bytes_in = 0
        self.mux_bytes_out = 0
        self.mux_bytes_in = 0

    def send(self, frame_bytes: bytes) -> None:
        self.frames_out += 1
        self.bytes_out += len(frame_bytes)
        if self.channel is not None:
            wrapped = wire_frames.encode_mux(self.channel, frame_bytes)
            self.mux_bytes_out += len(wrapped) - len(frame_bytes)
            frame_bytes = wrapped
        self.transport.send(frame_bytes)

    def recv(self, timeout: float | None = _UNSET) -> tuple[int, bytes]:
        """Next whole frame as (msg_type, payload).

        ``timeout`` overrides the stream's default recv timeout for this
        call only (the hub's per-peer round-barrier deadline) and bounds
        the WHOLE frame, not each transport chunk — a peer trickling bytes
        cannot hold the call open past the deadline (partial data stays
        buffered for the next call).
        """
        if timeout is _UNSET:
            timeout = self._recv_timeout
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            got = split_frame(self._buf, self._off)
            if got is not None:
                msg_type, payload, self._off = got
                if self._off == len(self._buf):
                    self._buf.clear()
                    self._off = 0
                if self.channel is not None:
                    if msg_type != wire_frames.MSG_MUX:
                        raise WireError(
                            "unmultiplexed frame on a channel-tagged stream"
                        )
                    outer_len = framed_len(len(payload))
                    ch, msg_type, payload = wire_frames.decode_mux(payload)
                    if ch != self.channel:
                        raise WireError(
                            f"frame for channel {ch} on channel {self.channel}"
                        )
                    self.mux_bytes_in += outer_len - framed_len(len(payload))
                self.bytes_in += framed_len(len(payload))
                self.frames_in += 1
                return msg_type, payload
            remain = None if deadline is None else deadline - time.monotonic()
            if remain is not None and remain <= 0:
                raise TransportTimeout("frame recv deadline elapsed")
            self._buf += self.transport.recv(timeout=remain)
