"""Two-endpoint PBS reconciliation over real transports (DESIGN.md §9).

``AliceEndpoint`` and ``BobEndpoint`` split the in-process
``repro.recon.ReconcileServer`` into genuine peers that communicate *only*
via ``repro.wire``-encoded bytes over a ``Transport``: an in-memory duplex
for tests, a TCP loopback socket, or a simulated lossy/latent channel
behind the stop-and-wait ``ReliableTransport``.  Each endpoint keeps
driving the device-resident cohort pipeline for its own side — S
concurrent sessions still batch into fused kernel launches per round — and
both sides advance the *same* ``core.pbs`` round state machine, so
per-session results and measured wire ledgers are byte-identical to
``core.pbs.reconcile`` (asserted in tests/test_net_endpoints.py and
tests/test_recon_batch.py).

``HubEndpoint`` (DESIGN.md §10) scales the serving side to N concurrent
peers on channel-multiplexed transports: all peers' sessions fuse into one
shared cohort pipeline, with per-peer round-barrier deadlines so a
straggler or mid-protocol disconnect fails only its own peer.

With ``continuous=True`` every endpoint also reconciles *divergent
replicas continuously* (DESIGN.md §11): ``advance_epoch`` stages the next
epoch's set mutations, ``run_epoch``/``serve_epoch``/``serve`` exchange the
``MSG_EPOCH`` d̂ handshake and delta-patch the device-resident stores in
place, so a long-lived peer pays O(churn) — not O(|set|) — per epoch.

``repro.net.resilience`` (DESIGN.md §13) hardens all of it against real
failure: ``FaultPlan``/``ChaosTransport`` script seeded loss bursts,
duplication, reordering, corruption, partitions, and crash-restart under
any of the transports; a crashed-and-restarted peer re-attaches through
the ``MSG_RESUME`` handshake (``AliceEndpoint.resume`` against
``HubEndpoint.resume_peer``) and continues from its last completed round
barrier with zero store rebuilds; ``classify_error`` types every failure
for ``PeerOutcome.error_kind``; and ``degrade=True`` endpoints escalate
decode-budget-exhausted sessions instead of failing them.
"""
from .endpoint import AliceEndpoint, BobEndpoint, run_pair, run_pair_epoch
from .hub import HubEndpoint, PeerOutcome, run_hub, run_hub_epoch
from .resilience import ChaosTransport, FaultPlan, PeerDeadline, classify_error
from .transport import (
    FrameStream,
    InMemoryDuplex,
    ReliableTransport,
    SimulatedChannel,
    SocketTransport,
    Transport,
    TransportError,
    TransportTimeout,
    tcp_loopback_pair,
)

__all__ = [
    "AliceEndpoint",
    "BobEndpoint",
    "ChaosTransport",
    "FaultPlan",
    "FrameStream",
    "HubEndpoint",
    "InMemoryDuplex",
    "PeerDeadline",
    "PeerOutcome",
    "ReliableTransport",
    "SimulatedChannel",
    "SocketTransport",
    "Transport",
    "TransportError",
    "TransportTimeout",
    "classify_error",
    "run_hub",
    "run_hub_epoch",
    "run_pair",
    "run_pair_epoch",
    "tcp_loopback_pair",
]
