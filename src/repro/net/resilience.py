"""Fault injection and failure taxonomy for the PBS network stack (§13).

Two pieces:

* ``FaultPlan`` / ``ChaosTransport`` — a scripted, seeded fault injector
  wrapping any ``Transport``.  Faults are decided per *send operation
  index* from a frozen plan plus a seeded RNG, so a given (plan, op
  sequence) always injects the same faults: random loss, periodic loss
  bursts, duplication, adjacent-pair reordering, header corruption,
  op-indexed partitions (blackhole windows), and scripted crash — the
  machinery under the chaos soak, where K of N hub peers crash
  mid-epoch and resume via ``MSG_RESUME``.
* ``classify_error`` / ``PeerDeadline`` — the typed failure taxonomy
  ``PeerOutcome.error_kind`` reports, so tests and operators assert on
  failure *cause* instead of string-matching exception text.

Layering: chaos wraps the raw datagram channel, ``ReliableTransport``
wraps chaos — so injected loss/dup/reorder exercise the real ARQ recovery
path.  Corruption garbles the ARQ header byte (the one surface with no
structural redundancy): the ARQ layer detects it and surfaces a
``TransportError``, after which recovery is the ordinary suspend→resume
path — exactly how a TCP-like medium converts residual corruption into
connection failure rather than silent data damage.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.tow import EstimateOutOfRange
from repro.obs.trace import NULL_TRACER
from repro.wire.frames import WireError

from .transport import Transport, TransportError, TransportTimeout


class PeerDeadline(TransportError):
    """A hub peer missed its round-barrier deadline (straggler eviction).

    Raised by the hub's poll loop, never by a transport itself — distinct
    from ``TransportTimeout`` so ``classify_error`` can tell "the hub gave
    up waiting" from "the channel broke".
    """


def classify_error(err: BaseException | None) -> str | None:
    """Collapse an exception to the ``PeerOutcome.error_kind`` taxonomy.

    ``deadline`` — the hub's round-barrier deadline elapsed (or a recv
    deadline did); ``estimate`` — phase-0 d̂ left the PBS operating regime
    (``EstimateOutOfRange``: the pair belongs to the tree front end);
    ``wire`` — the peer spoke malformed or out-of-protocol bytes;
    ``transport`` — the channel itself failed (closed pipe, ARQ
    exhaustion, injected crash).  Wrapper exceptions are unwrapped through
    ``__cause__`` so an eviction that re-wraps the root failure still
    classifies by the root.  Anything else is ``"error"``; None stays
    None (no failure).  The two non-exception kinds (``degraded``,
    ``resumed``) are assigned by the hub's bookkeeping, not derived here.
    """
    fallback = None
    while err is not None:
        if isinstance(err, (PeerDeadline, TransportTimeout)):
            return "deadline"
        if isinstance(err, EstimateOutOfRange):
            return "estimate"
        if isinstance(err, WireError):
            return "wire"
        if isinstance(err, TransportError):
            fallback = "transport"       # keep digging for a root cause
        elif fallback is None:
            fallback = "error"
        err = err.__cause__
    return fallback


@dataclass(frozen=True)
class FaultPlan:
    """A frozen, seeded fault script for one ``ChaosTransport`` direction.

    Random faults (``loss``/``dup``/``reorder``/``corrupt``) are
    probabilities drawn from a ``seed``-determined RNG; scripted faults
    key off the send-operation index: ``burst_every``/``burst_len`` drop
    ``burst_len`` consecutive sends at the start of every
    ``burst_every``-send window, ``partitions`` blackholes whole
    ``[start_op, end_op)`` windows, and ``crash_after_sends`` kills the
    transport at that op — closing the wrapped channel (the peer observes
    a clean disconnect) or, with ``crash_silent``, going dark (the peer
    observes a straggler and the hub's deadline eviction fires).
    """

    seed: int = 0
    loss: float = 0.0
    burst_every: int = 0
    burst_len: int = 0
    dup: float = 0.0
    reorder: float = 0.0
    corrupt: float = 0.0
    corrupt_at: tuple = ()      # exact send ops to corrupt (scripted form)
    partitions: tuple = ()
    crash_after_sends: int | None = None
    crash_silent: bool = False


class ChaosTransport(Transport):
    """Inject a ``FaultPlan``'s faults into every send through ``inner``.

    Pure wrapper: no protocol knowledge, works over any ``Transport``.
    Wrap the *datagram* channel and run ``ReliableTransport`` on top so
    every injected fault exercises real ARQ recovery.  Counters
    (``sends``/``recvs``/``dropped``/``duplicated``/``reordered``/
    ``corrupted``) expose what was actually injected; ``crashed`` reports
    whether the scripted crash fired.
    """

    def __init__(
        self, inner: Transport, plan: FaultPlan, tracer=None
    ) -> None:
        super().__init__()
        self._inner = inner
        self._plan = plan
        # injected faults mark instants on the shared timeline so a chaos
        # soak's trace shows each drop/crash next to the ARQ recovery it
        # provoked; per-datagram, so guarded by ``enabled`` (DESIGN.md §14)
        self._tracer = tracer if tracer is not None else NULL_TRACER
        self._rng = np.random.default_rng(plan.seed)
        self._held: bytes | None = None    # reorder: datagram awaiting swap
        self.crashed = False
        self.sends = 0
        self.recvs = 0
        self.dropped = 0
        self.duplicated = 0
        self.reordered = 0
        self.corrupted = 0

    def _crash(self) -> None:
        self.crashed = True
        self._held = None
        if not self._plan.crash_silent:
            self._inner.close()

    def _dropped_at(self, op: int) -> bool:
        plan = self._plan
        for start, end in plan.partitions:
            if start <= op < end:
                return True
        if plan.burst_every and op % plan.burst_every < plan.burst_len:
            return True
        return plan.loss > 0.0 and float(self._rng.random()) < plan.loss

    def send(self, data: bytes) -> None:
        if self.crashed:
            raise TransportError("chaos: send on crashed transport")
        op = self.sends
        self.sends += 1
        self.bytes_out += len(data)
        plan = self._plan
        if plan.crash_after_sends is not None and op >= plan.crash_after_sends:
            self._crash()
            if self._tracer.enabled:
                self._tracer.instant("chaos.crash", cat="chaos", op=op,
                                     silent=plan.crash_silent)
            raise TransportError(f"chaos: scripted crash at send {op}")
        if self._dropped_at(op):
            self.dropped += 1
            if self._tracer.enabled:
                self._tracer.instant("chaos.drop", cat="chaos", op=op)
            return
        data = bytes(data)
        if op in plan.corrupt_at or (
            plan.corrupt > 0.0 and float(self._rng.random()) < plan.corrupt
        ):
            # garble the ARQ header byte: detected, never silent damage
            data = bytes((data[0] ^ 0x80,)) + data[1:] if data else data
            self.corrupted += 1
            if self._tracer.enabled:
                self._tracer.instant("chaos.corrupt", cat="chaos", op=op)
        if self._held is not None:
            held, self._held = self._held, None
            self._inner.send(data)       # adjacent swap completes
            self._inner.send(held)
            self.reordered += 1
            if self._tracer.enabled:
                self._tracer.instant("chaos.reorder", cat="chaos", op=op)
        elif plan.reorder > 0.0 and float(self._rng.random()) < plan.reorder:
            self._held = data            # hold until the next delivered send
        else:
            self._inner.send(data)
            if plan.dup > 0.0 and float(self._rng.random()) < plan.dup:
                self._inner.send(data)
                self.duplicated += 1
                if self._tracer.enabled:
                    self._tracer.instant("chaos.dup", cat="chaos", op=op)

    def recv(self, timeout: float | None = None) -> bytes:
        if self.crashed:
            # the crashed side's own process is gone either way — it fails
            # fast; the *remote* side experiences the silent variant as
            # pure silence because the wrapped channel was never closed
            raise TransportError("chaos: recv on crashed transport")
        data = self._inner.recv(timeout=timeout)
        self.recvs += 1
        self.bytes_in += len(data)
        return data

    def linger(self, budget: float | None = None) -> None:
        if not self.crashed:
            self._inner.linger(budget)

    def close(self) -> None:
        self._inner.close()
