"""whisper-tiny [audio] — enc-dec, conv frontend stubbed (input_specs feeds
precomputed frame embeddings).  4L enc + 4L dec, d=384 6H d_ff=1536
vocab=51865 [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,            # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    norm_type="layernorm",
    act="gelu",
    frontend="audio_stub",
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128, vocab=512,
)
