"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
60L d_model=5120 128H moe_d_ff=1536 vocab=102400 [arXiv:2405.04434]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,
    vocab=102400,
    use_mla=True,
    kv_lora=512,
    q_lora=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    moe_top_k=6,
    moe_d_ff=1536,
    n_dense_layers=1,
    norm_type="rmsnorm",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
    kv_lora=64, q_lora=96, rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
    n_experts=8, n_shared_experts=1, moe_top_k=2, moe_d_ff=64, n_dense_layers=1,
    moe_token_chunk=256,
)
