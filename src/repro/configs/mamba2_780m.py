"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
48L d_model=1536 d_ff=0 vocab=50280 ssm_state=128  [arXiv:2405.21060]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=48,          # d_inner / headdim = 1536*2/64
    n_kv_heads=48,
    d_ff=0,              # SSD blocks only — no separate MLP (per config)
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_ngroups=1,
    ssm_conv=4,
    norm_type="rmsnorm",
    tie_embeddings=True,
    sub_quadratic=True,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab=256,
    ssm_state=16, ssm_headdim=32, ssm_chunk=32,
)
