"""internlm2-1.8b [dense] — GQA kv=8.  24L d=2048 16H d_ff=8192 vocab=92544
[arXiv:2403.17297]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92544,
    rope_theta=1e6,
    norm_type="rmsnorm",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
)
