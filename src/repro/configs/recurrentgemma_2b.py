"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, pattern
(rglru, rglru, attn).  26L d=2560 10H (kv=1) d_ff=7680 vocab=256000
[arXiv:2402.19427]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    window=2048,
    norm_type="rmsnorm",
    act="gelu",          # gated GeLU (GeGLU)
    tie_embeddings=True,
    logit_softcap=30.0,
    sub_quadratic=True,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=6, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32, d_ff=256,
    vocab=512, lru_width=128, window=64,
)
