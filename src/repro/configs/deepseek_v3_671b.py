"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8.
61L d_model=7168 128H (kv via MLA lora=512) moe_d_ff=2048 vocab=129280
[arXiv:2412.19437].  MTP head is a training-loss add-on; systems behaviour is
unchanged, so it is represented by the optional `mtp` flag (off by default —
see DESIGN.md §8)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,          # dense layers' FFN
    vocab=129280,
    use_mla=True,
    kv_lora=512,
    q_lora=1536,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    n_dense_layers=3,
    norm_type="rmsnorm",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=3, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
    kv_lora=64, q_lora=96, rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
    n_experts=8, moe_top_k=2, moe_d_ff=64, n_dense_layers=1, moe_token_chunk=256,
)
