"""pixtral-12b [vlm] — mistral-nemo-style decoder; the pixtral ViT frontend is
a STUB (input_specs provides precomputed patch embeddings that replace the
leading positions).  40L d=5120 32H (kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e9,
    norm_type="rmsnorm",
    frontend="patch_stub",
    n_frontend_tokens=1024,   # patch positions per sample in mixed batches
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
    vocab=512, n_frontend_tokens=8,
)
