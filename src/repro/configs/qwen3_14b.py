"""qwen3-14b [dense] — GQA kv=8, qk_norm.  40L d=5120 40H d_ff=17408
vocab=151936 [hf:Qwen/Qwen3-14B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    norm_type="rmsnorm",
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16, d_ff=256, vocab=512,
)
