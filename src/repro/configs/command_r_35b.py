"""command-r-35b [dense] — GQA kv=8, no biases.  40L d=8192 64H d_ff=22528
vocab=256000 [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    norm_type="layernorm",   # cohere uses LayerNorm (no bias)
    act="swiglu",
    rope_theta=8e6,
    tie_embeddings=True,
)

SMOKE_CONFIG = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256, vocab=512,
)
