"""Assigned architecture registry: ``get_config(arch_id)`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "mamba2-780m",
    "deepseek-v3-671b",
    "deepseek-v2-236b",
    "qwen3-14b",
    "command-r-35b",
    "qwen2-1.5b",
    "internlm2-1.8b",
    "whisper-tiny",
    "recurrentgemma-2b",
    "pixtral-12b",
)

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MOD:
        raise KeyError(f"unknown arch {arch_id!r}; choices: {sorted(_MOD)}")
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str):
    mod = importlib.import_module(f"repro.configs.{_MOD[arch_id]}")
    return mod.SMOKE_CONFIG
