"""Atomic sharded checkpoints + PBS-reconciled manifest sync."""
from .manager import (  # noqa: F401
    BLOCK_BYTES,
    Manifest,
    SyncReport,
    latest_step,
    load_manifest,
    reconcile_manifests,
    restore_checkpoint,
    save_checkpoint,
    signature,
    sync_checkpoint,
)
