"""Fault-tolerant sharded checkpointing with PBS-reconciled manifests.

Layout of a checkpoint directory::

    step_000120/
      MANIFEST.json        # {"step":…, "shards": {shard_id: {leaf, slot, hash, bytes}}}
      <shard_id>.npy       # one block of one flattened leaf (BLOCK_BYTES each)

Shards are content-addressed: ``shard_id = blake2b(leaf_path, slot)`` and the
manifest records a content hash per shard.  Writes are atomic (tmp dir +
``os.replace``); a crash mid-save never corrupts the previous checkpoint.

**PBS integration (the paper's technique as a first-class feature).**  A
recovering / rejoining host holds an older or partial checkpoint; instead of
shipping the full manifest (O(#shards · entry) bytes) the two hosts run the
PBS set-reconciliation protocol over 32-bit shard *signatures*
(hash(shard_id, content_hash)): ``d`` = number of differing shards is tiny
after a short outage, so PBS finds the exact missing/stale set in O(d)
decode time and ~2× the information-theoretic minimum bytes (paper §1.3),
and only those shards' payloads move.  ``sync_checkpoint`` below does this
end-to-end on real directories and reports the byte ledger vs. a naive
manifest exchange.

Elastic re-sharding: shards store *global* leaf blocks, so restoring onto a
different mesh is just ``device_put`` with the new sharding — the checkpoint
format is mesh-independent (tests/test_checkpoint.py exercises 1→(2,4)).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import time
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.core.pbs import PBSConfig, reconcile

BLOCK_BYTES = 1 << 22  # 4 MiB shards


# ---------------------------------------------------------------------------
# tree <-> flat leaves
# ---------------------------------------------------------------------------


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}" if prefix else k))
        return out
    out[prefix] = tree
    return out


def _unflatten(leaves: dict):
    tree: dict = {}
    for path, v in leaves.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _shard_id(leaf: str, slot: int) -> str:
    return hashlib.blake2b(f"{leaf}#{slot}".encode(), digest_size=10).hexdigest()


def _content_hash(arr: np.ndarray) -> str:
    return hashlib.blake2b(arr.tobytes(), digest_size=10).hexdigest()


def signature(shard_id: str, content_hash: str) -> int:
    """32-bit signature of a manifest entry — the PBS set element."""
    h = hashlib.blake2b(f"{shard_id}:{content_hash}".encode(), digest_size=4)
    sig = int.from_bytes(h.digest(), "little")
    return sig or 1  # 0 is excluded from the PBS universe (paper §2.1)


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------


@dataclass
class Manifest:
    step: int
    shards: dict            # shard_id -> {leaf, slot, hash, bytes, shape?, dtype?}
    leaves: dict            # leaf -> {shape, dtype, n_slots}

    def signatures(self) -> np.ndarray:
        return np.array(
            [signature(s, e["hash"]) for s, e in self.shards.items()], dtype=np.uint32
        )

    def by_signature(self) -> dict:
        return {signature(s, e["hash"]): s for s, e in self.shards.items()}


def save_checkpoint(root: str | Path, step: int, tree, *, keep: int = 3) -> Manifest:
    """Atomic sharded save of a pytree of (host or device) arrays."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=root, prefix=".tmp_save_"))
    leaves = _flatten(tree)
    shards, leaf_meta = {}, {}
    try:
        for leaf, arr in leaves.items():
            a = np.asarray(arr)
            # byte-level blocks: dtype-agnostic (bf16 etc. survive the trip)
            flat = np.ascontiguousarray(a).reshape(-1).view(np.uint8)
            per = BLOCK_BYTES
            n_slots = max(1, -(-flat.size // per))
            leaf_meta[leaf] = {
                "shape": list(a.shape), "dtype": str(a.dtype), "n_slots": n_slots, "per": per,
            }
            for slot in range(n_slots):
                blk = flat[slot * per : (slot + 1) * per]
                sid = _shard_id(leaf, slot)
                np.save(tmp / f"{sid}.npy", blk)
                shards[sid] = {
                    "leaf": leaf, "slot": slot,
                    "hash": _content_hash(blk), "bytes": int(blk.nbytes),
                }
        man = {"step": step, "time": time.time(), "shards": shards, "leaves": leaf_meta}
        (tmp / "MANIFEST.json").write_text(json.dumps(man))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(root, keep)
    return Manifest(step, shards, leaf_meta)


def _gc(root: Path, keep: int):
    steps = sorted(p for p in root.iterdir() if p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root: str | Path) -> int | None:
    root = Path(root)
    if not root.exists():
        return None
    steps = sorted(
        int(p.name.split("_")[1]) for p in root.iterdir()
        if p.name.startswith("step_") and (p / "MANIFEST.json").exists()
    )
    return steps[-1] if steps else None


def load_manifest(root: str | Path, step: int) -> Manifest:
    d = Path(root) / f"step_{step:08d}"
    man = json.loads((d / "MANIFEST.json").read_text())
    return Manifest(man["step"], man["shards"], man["leaves"])


def restore_checkpoint(root: str | Path, step: int | None = None):
    """Rebuild the global pytree from shards (mesh-independent)."""
    root = Path(root)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = root / f"step_{step:08d}"
    man = load_manifest(root, step)
    leaves = {}
    for leaf, meta in man.leaves.items():
        parts = []
        for slot in range(meta["n_slots"]):
            sid = _shard_id(leaf, slot)
            parts.append(np.load(d / f"{sid}.npy"))
        flat = np.concatenate(parts) if len(parts) > 1 else parts[0]
        leaves[leaf] = flat.view(_np_dtype(meta["dtype"])).reshape(meta["shape"])
    return _unflatten(leaves), step


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


# ---------------------------------------------------------------------------
# PBS-reconciled checkpoint sync
# ---------------------------------------------------------------------------


@dataclass
class SyncReport:
    step: int
    shards_fetched: int
    shards_deleted: int
    payload_bytes: int
    pbs_bytes: int            # reconciliation protocol bytes (both directions)
    naive_bytes: int          # full-manifest exchange cost
    rounds: int
    success: bool


def reconcile_manifests(local: Manifest, remote: Manifest, seed: int = 0):
    """PBS set reconciliation over shard signatures.

    Returns (to_fetch shard_ids, to_delete shard_ids, ReconcileResult).
    Alice = the local (stale) host; it learns the symmetric difference and
    resolves each differing signature against the remote manifest.
    """
    a = local.signatures()
    b = remote.signatures()
    res = reconcile(a, b, PBSConfig(seed=seed))
    by_sig_remote = remote.by_signature()
    by_sig_local = local.by_signature()
    to_fetch = [by_sig_remote[s] for s in res.diff if s in by_sig_remote]
    to_delete = [
        by_sig_local[s] for s in res.diff
        if s in by_sig_local and by_sig_local[s] not in remote.shards
    ]
    return to_fetch, to_delete, res


def sync_checkpoint(src_root: str | Path, dst_root: str | Path, *, seed: int = 0) -> SyncReport:
    """Bring dst up to date with src's latest checkpoint, moving only the
    shards PBS identifies as different."""
    src_root, dst_root = Path(src_root), Path(dst_root)
    step = latest_step(src_root)
    assert step is not None, f"nothing to sync from {src_root}"
    remote = load_manifest(src_root, step)

    local_step = latest_step(dst_root)
    if local_step is None:
        local = Manifest(-1, {}, {})
        src_dir = src_root / f"step_{step:08d}"
        dst_dir = dst_root / f"step_{step:08d}"
        shutil.copytree(src_dir, dst_dir, dirs_exist_ok=True)
        payload = sum(e["bytes"] for e in remote.shards.values())
        return SyncReport(step, len(remote.shards), 0, payload, 0,
                          _manifest_bytes(remote), 1, True)
    local = load_manifest(dst_root, local_step)

    to_fetch, to_delete, res = reconcile_manifests(local, remote, seed)
    src_dir = src_root / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=dst_root, prefix=".tmp_sync_"))
    try:
        # start from the local checkpoint's shards (hardlink-as-copy), then patch
        local_dir = dst_root / f"step_{local_step:08d}"
        for f in local_dir.glob("*.npy"):
            shutil.copy2(f, tmp / f.name)
        payload = 0
        for sid in to_fetch:
            shutil.copy2(src_dir / f"{sid}.npy", tmp / f"{sid}.npy")
            payload += remote.shards[sid]["bytes"]
        for sid in to_delete:
            p = tmp / f"{sid}.npy"
            if p.exists():
                p.unlink()
        shutil.copy2(src_dir / "MANIFEST.json", tmp / "MANIFEST.json")
        final = dst_root / f"step_{step:08d}"
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return SyncReport(
        step=step,
        shards_fetched=len(to_fetch),
        shards_deleted=len(to_delete),
        payload_bytes=payload,
        pbs_bytes=res.bytes_sent + res.estimator_bytes,
        naive_bytes=_manifest_bytes(remote),
        rounds=res.rounds,
        success=res.success,
    )


def _manifest_bytes(man: Manifest) -> int:
    return len(json.dumps(man.shards).encode())
